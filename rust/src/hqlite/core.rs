//! The hqlite server state machine (pure logic, both planes).

use std::collections::HashMap;

use crate::cluster::JobRequest;
use crate::clock::Micros;
use crate::metrics::JobRecord;

pub type TaskId = u64;
pub type WorkerId = u64;

/// One task submitted to the HQ server.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub tag: u64,
    pub cores: u32,
    /// Scheduling hint: expected runtime (HQ `--time-request`).
    pub time_request: Micros,
    /// Hard kill limit (HQ `--time-limit`).
    pub time_limit: Micros,
}

/// Automatic-allocation configuration (the paper's section II.D example:
/// `--backlog 1 --workers-per-alloc 1 --max-worker-count N`).
#[derive(Clone, Debug)]
pub struct AutoAllocConfig {
    /// Max allocations waiting in the native queue at once.
    pub backlog: u32,
    /// Workers started per allocation.
    pub workers_per_alloc: u32,
    /// Upper bound on simultaneously existing workers.
    pub max_worker_count: u32,
    /// Resources requested per allocation (cores sized for one worker).
    pub alloc_request: JobRequest,
    /// Per-task dispatch latency (server -> worker handoff).
    pub dispatch_latency: Micros,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum TaskState {
    Pending,
    Dispatched,
    Running,
    Done,
}

#[derive(Clone, Debug)]
struct Task {
    spec: TaskSpec,
    state: TaskState,
    submit_t: Micros,
    start_t: Micros,
    worker: WorkerId,
}

#[derive(Clone, Debug)]
struct Worker {
    /// Cores available on the worker.
    cores: u32,
    cores_free: u32,
    /// Virtual time at which the surrounding allocation expires.
    expires_t: Micros,
    alive: bool,
    /// Running task count (for idle tests).
    running: u32,
}

/// Actions the driver must interpret.
#[derive(Clone, Debug)]
pub enum HqAction {
    /// Submit an allocation to the native scheduler (tag it so the driver
    /// can route the eventual worker registration back).
    SubmitAllocation { alloc_tag: u64, req: JobRequest },
    /// Begin task execution on a worker: the driver runs the workload and
    /// calls [`HqCore::on_task_done`] (sim: after the sampled duration).
    StartTask { task: TaskId, worker: WorkerId },
    /// Kill the task (exceeded its time limit).
    KillTask { task: TaskId },
    /// Terminal per-task record.
    TaskCompleted { task: TaskId, record: JobRecord },
    /// Re-invoke `on_timer` at this time.
    Timer(Micros, HqTimer),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HqTimer {
    /// Dispatch latency elapsed: task actually starts on the worker.
    Dispatched(TaskId),
    /// Task time-limit enforcement.
    Limit(TaskId),
}

/// The HQ server.
pub struct HqCore {
    cfg: AutoAllocConfig,
    tasks: HashMap<TaskId, Task>,
    queue: Vec<TaskId>,
    workers: HashMap<WorkerId, Worker>,
    next_task: TaskId,
    next_worker: WorkerId,
    next_alloc_tag: u64,
    /// Allocations submitted to the native scheduler, not yet up.
    allocs_in_queue: u32,
    workers_started: u32,
    /// Stats: dispatches performed.
    pub dispatches: u64,
}

impl HqCore {
    pub fn new(cfg: AutoAllocConfig) -> Self {
        HqCore {
            cfg,
            tasks: HashMap::new(),
            queue: Vec::new(),
            workers: HashMap::new(),
            next_task: 1,
            next_worker: 1,
            next_alloc_tag: 1,
            allocs_in_queue: 0,
            workers_started: 0,
            dispatches: 0,
        }
    }

    /// Submit a task; may trigger autoalloc and immediate dispatch.
    pub fn submit_task(&mut self, t: Micros, spec: TaskSpec) -> (TaskId, Vec<HqAction>) {
        let id = self.next_task;
        self.next_task += 1;
        self.tasks.insert(
            id,
            Task {
                spec,
                state: TaskState::Pending,
                submit_t: t,
                start_t: 0,
                worker: 0,
            },
        );
        self.queue.push(id);
        let mut acts = self.autoalloc();
        acts.extend(self.dispatch(t));
        (id, acts)
    }

    /// A native allocation came up: start `workers_per_alloc` workers,
    /// each living until the allocation's time limit.
    pub fn on_alloc_up(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
    ) -> Vec<HqAction> {
        self.allocs_in_queue = self.allocs_in_queue.saturating_sub(1);
        for _ in 0..self.cfg.workers_per_alloc {
            if self.live_workers() as u32 >= self.cfg.max_worker_count {
                break;
            }
            let wid = self.next_worker;
            self.next_worker += 1;
            self.workers.insert(
                wid,
                Worker {
                    cores: cores_per_worker,
                    cores_free: cores_per_worker,
                    expires_t: t + time_limit,
                    alive: true,
                    running: 0,
                },
            );
            self.workers_started += 1;
        }
        self.dispatch(t)
    }

    /// A worker disappeared (allocation ended); requeue its tasks.
    pub fn on_worker_lost(&mut self, t: Micros, wid: WorkerId) -> Vec<HqAction> {
        if let Some(w) = self.workers.get_mut(&wid) {
            w.alive = false;
        }
        let mut requeued = Vec::new();
        for (id, task) in self.tasks.iter_mut() {
            if task.worker == wid
                && matches!(task.state, TaskState::Running | TaskState::Dispatched)
            {
                task.state = TaskState::Pending;
                requeued.push(*id);
            }
        }
        self.queue.extend(requeued);
        let mut acts = self.autoalloc();
        acts.extend(self.dispatch(t));
        acts
    }

    /// Driver reports a task's workload finished.
    pub fn on_task_done(&mut self, t: Micros, id: TaskId) -> Vec<HqAction> {
        self.complete(t, id, false)
    }

    pub fn on_timer(&mut self, t: Micros, timer: HqTimer) -> Vec<HqAction> {
        match timer {
            HqTimer::Dispatched(id) => {
                let Some(task) = self.tasks.get_mut(&id) else { return vec![] };
                if task.state != TaskState::Dispatched {
                    return vec![];
                }
                task.state = TaskState::Running;
                task.start_t = t;
                let worker = task.worker;
                let limit = task.spec.time_limit;
                vec![
                    HqAction::StartTask { task: id, worker },
                    HqAction::Timer(t + limit, HqTimer::Limit(id)),
                ]
            }
            HqTimer::Limit(id) => {
                let running = matches!(
                    self.tasks.get(&id).map(|x| x.state),
                    Some(TaskState::Running)
                );
                if running {
                    let mut acts = vec![HqAction::KillTask { task: id }];
                    acts.extend(self.complete(t, id, true));
                    acts
                } else {
                    vec![]
                }
            }
        }
    }

    fn complete(&mut self, t: Micros, id: TaskId, truncated: bool) -> Vec<HqAction> {
        let Some(task) = self.tasks.get_mut(&id) else { return vec![] };
        if task.state == TaskState::Done {
            return vec![];
        }
        task.state = TaskState::Done;
        let record = JobRecord {
            tag: task.spec.tag,
            submit: task.submit_t,
            start: task.start_t,
            end: t,
            // HQ CPU time: from task start on the worker (includes the
            // model-server init the driver folds into the duration).
            cpu: t.saturating_sub(task.start_t),
            truncated,
        };
        let wid = task.worker;
        let cores = task.spec.cores;
        if let Some(w) = self.workers.get_mut(&wid) {
            w.cores_free += cores;
            w.running = w.running.saturating_sub(1);
        }
        let mut acts = vec![HqAction::TaskCompleted { task: id, record }];
        acts.extend(self.dispatch(t));
        acts
    }

    /// Submit allocations while there are pending tasks, the backlog
    /// allows it, and the worker cap is not reached.
    fn autoalloc(&mut self) -> Vec<HqAction> {
        let mut acts = Vec::new();
        while !self.queue.is_empty()
            && self.allocs_in_queue < self.cfg.backlog
            && self.live_workers() as u32
                + self.allocs_in_queue * self.cfg.workers_per_alloc
                < self.cfg.max_worker_count
        {
            self.allocs_in_queue += 1;
            let tag = self.next_alloc_tag;
            self.next_alloc_tag += 1;
            acts.push(HqAction::SubmitAllocation {
                alloc_tag: tag,
                req: self.cfg.alloc_request.clone(),
            });
        }
        acts
    }

    /// FCFS dispatch honouring cores and the time-request semantics.
    fn dispatch(&mut self, t: Micros) -> Vec<HqAction> {
        let mut acts = Vec::new();
        let mut remaining: Vec<TaskId> = Vec::new();
        let queue = std::mem::take(&mut self.queue);
        for id in queue {
            let task = &self.tasks[&id];
            if task.state != TaskState::Pending {
                continue;
            }
            // A worker qualifies if it is alive, has the cores free, and
            // its allocation will outlive the task's *time request*.
            let need = task.spec.cores;
            let tr = task.spec.time_request;
            let pick = self
                .workers
                .iter()
                .filter(|(_, w)| {
                    w.alive && w.cores_free >= need && w.expires_t >= t + tr
                })
                .min_by_key(|(wid, _)| **wid)
                .map(|(wid, _)| *wid);
            match pick {
                Some(wid) => {
                    let w = self.workers.get_mut(&wid).unwrap();
                    w.cores_free -= need;
                    w.running += 1;
                    let task = self.tasks.get_mut(&id).unwrap();
                    task.state = TaskState::Dispatched;
                    task.worker = wid;
                    self.dispatches += 1;
                    acts.push(HqAction::Timer(
                        t + self.cfg.dispatch_latency,
                        HqTimer::Dispatched(id),
                    ));
                }
                None => remaining.push(id),
            }
        }
        self.queue = remaining;
        // Unschedulable tasks may need more allocations.
        acts.extend(self.autoalloc());
        acts
    }

    /// Expire workers whose allocation has ended (driver calls this when
    /// the native allocation job finishes); requeues their tasks and
    /// replaces capacity via autoalloc.
    pub fn expire_workers(&mut self, t: Micros) -> Vec<HqAction> {
        let expired: Vec<WorkerId> = self
            .workers
            .iter()
            .filter(|(_, w)| w.alive && w.expires_t <= t)
            .map(|(id, _)| *id)
            .collect();
        let mut acts = Vec::new();
        for wid in expired {
            acts.extend(self.on_worker_lost(t, wid));
        }
        acts
    }

    // ---- introspection ---------------------------------------------------

    pub fn pending_tasks(&self) -> usize {
        self.queue.len()
    }

    pub fn live_workers(&self) -> usize {
        self.workers.values().filter(|w| w.alive).count()
    }

    pub fn allocs_waiting(&self) -> u32 {
        self.allocs_in_queue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{Des, MS, SEC};

    fn cfg() -> AutoAllocConfig {
        AutoAllocConfig {
            backlog: 1,
            workers_per_alloc: 1,
            max_worker_count: 4,
            alloc_request: JobRequest::new(16, 16, 3600 * SEC),
            dispatch_latency: 1 * MS,
        }
    }

    /// Sim-drive: allocations come up `alloc_delay` after submission;
    /// tasks run `dur(tag)`.
    fn drive(
        core: &mut HqCore,
        submissions: Vec<(Micros, TaskSpec)>,
        alloc_delay: Micros,
        dur: impl Fn(u64) -> Micros,
    ) -> Vec<JobRecord> {
        #[derive(Debug)]
        enum Ev {
            Submit(TaskSpec),
            AllocUp,
            Timer(HqTimer),
            TaskDone(TaskId),
        }
        let mut des: Des<Ev> = Des::new();
        for (t, s) in submissions {
            des.schedule(t, Ev::Submit(s));
        }
        let mut records = Vec::new();
        let mut guard = 0;
        while let Some((t, ev)) = des.pop() {
            guard += 1;
            assert!(guard < 1_000_000, "runaway");
            let acts = match ev {
                Ev::Submit(s) => core.submit_task(t, s).1,
                Ev::AllocUp => core.on_alloc_up(t, 3600 * SEC, 16),
                Ev::Timer(tm) => core.on_timer(t, tm),
                Ev::TaskDone(id) => core.on_task_done(t, id),
            };
            for a in acts {
                match a {
                    HqAction::SubmitAllocation { .. } => {
                        des.schedule(t + alloc_delay, Ev::AllocUp)
                    }
                    HqAction::StartTask { task, .. } => {
                        let tag = records.len() as u64; // not used for dur
                        let _ = tag;
                        des.schedule(t + dur(task), Ev::TaskDone(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record)
                    }
                    HqAction::KillTask { .. } => {}
                }
            }
        }
        records
    }

    #[test]
    fn single_task_through_alloc() {
        let mut core = HqCore::new(cfg());
        let recs = drive(
            &mut core,
            vec![(0, TaskSpec { tag: 1, cores: 1, time_request: SEC,
                                time_limit: 10 * SEC })],
            30 * SEC, // allocation queue wait
            |_| 2 * SEC,
        );
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        // Start only after the allocation came up (30 s) + dispatch (1 ms).
        assert!(r.start >= 30 * SEC);
        assert!(r.start <= 30 * SEC + 10 * MS);
        assert_eq!(r.cpu, 2 * SEC);
        // Overhead = queue wait + dispatch, NOT per-task sbatch costs.
        assert!(r.overhead() >= 30 * SEC);
    }

    #[test]
    fn later_tasks_have_tiny_overhead() {
        // The paper's core claim: after the first allocation, per-task
        // overhead collapses to dispatch latency (ms).
        let mut core = HqCore::new(cfg());
        let subs: Vec<_> = (0..10)
            .map(|i| (i as Micros, TaskSpec {
                tag: i, cores: 16, time_request: SEC, time_limit: 100 * SEC,
            }))
            .collect();
        let recs = drive(&mut core, subs, 60 * SEC, |_| SEC);
        assert_eq!(recs.len(), 10);
        let mut overheads: Vec<_> = recs.iter().map(|r| r.overhead()).collect();
        overheads.sort();
        // First task pays the allocation wait...
        assert!(*overheads.last().unwrap() >= 60 * SEC);
        // ...subsequent ones only the dispatch (served serially on one
        // 16-core worker, so overhead includes waiting for the previous
        // task; the *scheduler* overhead per task is ms).  Check that at
        // least the dispatch-only component is visible on task 2's start:
        let mut starts: Vec<_> = recs.iter().map(|r| r.start).collect();
        starts.sort();
        let gap = starts[1] - starts[0];
        assert!(gap >= SEC && gap <= SEC + 50 * MS,
                "serial tasks start back-to-back, gap {gap}");
    }

    #[test]
    fn time_request_gates_dispatch() {
        let mut core = HqCore::new(cfg());
        // Allocation lives 10 s; task requests 3600 s: must NOT dispatch.
        let (id, acts) = core.submit_task(0, TaskSpec {
            tag: 1, cores: 1, time_request: 3600 * SEC, time_limit: 2 * 3600 * SEC,
        });
        // Process the allocation coming up with a 10 s lifetime.
        let mut up = core.on_alloc_up(0, 10 * SEC, 16);
        up.extend(acts);
        assert!(core.pending_tasks() == 1,
                "task with long time request stays queued");
        let _ = id;
    }

    #[test]
    fn time_limit_kills_runaway() {
        let mut core = HqCore::new(cfg());
        let recs = drive(
            &mut core,
            vec![(0, TaskSpec { tag: 9, cores: 1, time_request: SEC,
                                time_limit: 5 * SEC })],
            SEC,
            |_| 60 * SEC, // runs way past the limit
        );
        assert_eq!(recs.len(), 1);
        assert!(recs[0].truncated);
        assert!(recs[0].cpu <= 5 * SEC + MS);
    }

    #[test]
    fn backlog_bounds_queued_allocations() {
        let mut core = HqCore::new(AutoAllocConfig { backlog: 2, ..cfg() });
        let mut alloc_submissions = 0;
        for i in 0..8 {
            let (_, acts) = core.submit_task(i, TaskSpec {
                tag: i, cores: 1, time_request: SEC, time_limit: 10 * SEC,
            });
            alloc_submissions += acts.iter()
                .filter(|a| matches!(a, HqAction::SubmitAllocation { .. }))
                .count();
        }
        assert_eq!(alloc_submissions, 2, "backlog=2 caps queued allocs");
        assert_eq!(core.allocs_waiting(), 2);
    }

    #[test]
    fn max_worker_count_respected() {
        let mut core = HqCore::new(AutoAllocConfig {
            backlog: 10, max_worker_count: 2, ..cfg()
        });
        for i in 0..10 {
            core.submit_task(i, TaskSpec {
                tag: i, cores: 16, time_request: SEC, time_limit: 10 * SEC,
            });
        }
        core.on_alloc_up(10, 3600 * SEC, 16);
        core.on_alloc_up(11, 3600 * SEC, 16);
        core.on_alloc_up(12, 3600 * SEC, 16);
        assert!(core.live_workers() <= 2);
    }

    #[test]
    fn worker_loss_requeues_tasks() {
        let mut core = HqCore::new(cfg());
        let (id, _) = core.submit_task(0, TaskSpec {
            tag: 1, cores: 1, time_request: SEC, time_limit: 100 * SEC,
        });
        let acts = core.on_alloc_up(0, 3600 * SEC, 16);
        // Fire the dispatch timer.
        let mut started = false;
        for a in acts {
            if let HqAction::Timer(t, tm) = a {
                for b in core.on_timer(t, tm) {
                    if matches!(b, HqAction::StartTask { .. }) {
                        started = true;
                    }
                }
            }
        }
        assert!(started);
        let wid = 1;
        core.on_worker_lost(5 * SEC, wid);
        assert_eq!(core.pending_tasks(), 1, "running task requeued");
        let _ = id;
    }

    #[test]
    fn parallel_tasks_share_worker_cores() {
        // 16-core worker, 8-core tasks: two run concurrently.
        let mut core = HqCore::new(cfg());
        let subs: Vec<_> = (0..2)
            .map(|i| (0, TaskSpec {
                tag: i, cores: 8, time_request: SEC, time_limit: 100 * SEC,
            }))
            .collect();
        let recs = drive(&mut core, subs, SEC, |_| 10 * SEC);
        assert_eq!(recs.len(), 2);
        let starts: Vec<_> = recs.iter().map(|r| r.start).collect();
        assert!((starts[0] as i64 - starts[1] as i64).abs() < MS as i64 * 10,
                "both start together: {starts:?}");
    }
}
