//! Reference hqlite core: the pre-index seed semantics, kept verbatim.
//!
//! The O(n)-everything implementation the indexed
//! [`HqCore`](super::core::HqCore) replaced: every dispatch clones and
//! rescans the whole task queue, every candidate task scans every worker
//! ever registered, worker loss scans every task ever submitted, and
//! worker expiry iterates all workers.  Kept for:
//!
//! 1. **Equivalence testing** — `tests/scheduler_props.rs` asserts the
//!    indexed core produces identical record streams on random traces.
//! 2. **Baseline benchmarking** — `benches/scale.rs` measures speedup
//!    against this core.
//!
//! One deliberate difference from the raw seed: requeue order on worker
//! loss and multi-worker expiry order were HashMap-iteration dependent
//! (nondeterministic across processes); here both are sorted — ascending
//! task id, (expires, worker id) — matching the indexed core.  The seed
//! never relied on a particular order.

use std::collections::HashMap;

use crate::clock::Micros;
use crate::metrics::JobRecord;

use super::core::{AutoAllocConfig, HqAction, HqTimer, TaskId, TaskSpec, WorkerId};

#[derive(Clone, Copy, Debug, PartialEq)]
enum TaskState {
    Pending,
    Dispatched,
    Running,
    Done,
}

#[derive(Clone, Debug)]
struct Task {
    spec: TaskSpec,
    state: TaskState,
    submit_t: Micros,
    start_t: Micros,
    worker: WorkerId,
}

#[derive(Clone, Debug)]
struct Worker {
    cores_free: u32,
    expires_t: Micros,
    alive: bool,
}

/// Seed-semantics HQ server (naive queue and worker scans).
pub struct ReferenceHqCore {
    cfg: AutoAllocConfig,
    tasks: HashMap<TaskId, Task>,
    queue: Vec<TaskId>,
    workers: HashMap<WorkerId, Worker>,
    next_task: TaskId,
    next_worker: WorkerId,
    next_alloc_tag: u64,
    allocs_in_queue: u32,
    workers_started: u32,
    pub dispatches: u64,
}

impl ReferenceHqCore {
    pub fn new(cfg: AutoAllocConfig) -> Self {
        ReferenceHqCore {
            cfg,
            tasks: HashMap::new(),
            queue: Vec::new(),
            workers: HashMap::new(),
            next_task: 1,
            next_worker: 1,
            next_alloc_tag: 1,
            allocs_in_queue: 0,
            workers_started: 0,
            dispatches: 0,
        }
    }

    pub fn submit_task(&mut self, t: Micros, spec: TaskSpec) -> (TaskId, Vec<HqAction>) {
        let id = self.next_task;
        self.next_task += 1;
        self.tasks.insert(
            id,
            Task {
                spec,
                state: TaskState::Pending,
                submit_t: t,
                start_t: 0,
                worker: 0,
            },
        );
        self.queue.push(id);
        let mut acts = self.autoalloc();
        acts.extend(self.dispatch(t));
        (id, acts)
    }

    pub fn on_alloc_up(
        &mut self,
        t: Micros,
        time_limit: Micros,
        cores_per_worker: u32,
    ) -> Vec<HqAction> {
        self.allocs_in_queue = self.allocs_in_queue.saturating_sub(1);
        for _ in 0..self.cfg.workers_per_alloc {
            if self.live_workers() as u32 >= self.cfg.max_worker_count {
                break;
            }
            let wid = self.next_worker;
            self.next_worker += 1;
            self.workers.insert(
                wid,
                Worker {
                    cores_free: cores_per_worker,
                    expires_t: t + time_limit,
                    alive: true,
                },
            );
            self.workers_started += 1;
        }
        self.dispatch(t)
    }

    pub fn on_worker_lost(&mut self, t: Micros, wid: WorkerId) -> Vec<HqAction> {
        if let Some(w) = self.workers.get_mut(&wid) {
            w.alive = false;
        }
        // Full task-table scan, as in the seed; sorted for determinism.
        let mut requeued = Vec::new();
        for (id, task) in self.tasks.iter_mut() {
            if task.worker == wid
                && matches!(task.state, TaskState::Running | TaskState::Dispatched)
            {
                task.state = TaskState::Pending;
                requeued.push(*id);
            }
        }
        requeued.sort_unstable();
        self.queue.extend(requeued);
        let mut acts = self.autoalloc();
        acts.extend(self.dispatch(t));
        acts
    }

    pub fn on_task_done(&mut self, t: Micros, id: TaskId) -> Vec<HqAction> {
        self.complete(t, id, false)
    }

    pub fn on_timer(&mut self, t: Micros, timer: HqTimer) -> Vec<HqAction> {
        match timer {
            HqTimer::Dispatched(id) => {
                let Some(task) = self.tasks.get_mut(&id) else { return vec![] };
                if task.state != TaskState::Dispatched {
                    return vec![];
                }
                task.state = TaskState::Running;
                task.start_t = t;
                let worker = task.worker;
                let limit = task.spec.time_limit;
                vec![
                    HqAction::StartTask { task: id, worker },
                    HqAction::Timer(t + limit, HqTimer::Limit(id)),
                ]
            }
            HqTimer::Limit(id) => {
                let running = matches!(
                    self.tasks.get(&id).map(|x| x.state),
                    Some(TaskState::Running)
                );
                if running {
                    let mut acts = vec![HqAction::KillTask { task: id }];
                    acts.extend(self.complete(t, id, true));
                    acts
                } else {
                    vec![]
                }
            }
        }
    }

    fn complete(&mut self, t: Micros, id: TaskId, truncated: bool) -> Vec<HqAction> {
        let Some(task) = self.tasks.get_mut(&id) else { return vec![] };
        if task.state == TaskState::Done {
            return vec![];
        }
        let was_running =
            matches!(task.state, TaskState::Running | TaskState::Dispatched);
        task.state = TaskState::Done;
        let record = JobRecord {
            tag: task.spec.tag,
            submit: task.submit_t,
            start: task.start_t,
            end: t,
            cpu: t.saturating_sub(task.start_t),
            truncated,
        };
        let wid = task.worker;
        let cores = task.spec.cores;
        if was_running {
            if let Some(w) = self.workers.get_mut(&wid) {
                w.cores_free += cores;
            }
        }
        let mut acts = vec![HqAction::TaskCompleted { task: id, record }];
        acts.extend(self.dispatch(t));
        acts
    }

    fn autoalloc(&mut self) -> Vec<HqAction> {
        let mut acts = Vec::new();
        while !self.queue.is_empty()
            && self.allocs_in_queue < self.cfg.backlog
            && self.live_workers() as u32
                + self.allocs_in_queue * self.cfg.workers_per_alloc
                < self.cfg.max_worker_count
        {
            self.allocs_in_queue += 1;
            let tag = self.next_alloc_tag;
            self.next_alloc_tag += 1;
            acts.push(HqAction::SubmitAllocation {
                alloc_tag: tag,
                req: self.cfg.alloc_request,
            });
        }
        acts
    }

    /// FCFS dispatch: clone-and-rebuild queue scan, full worker scan per
    /// candidate (the seed behaviour the indexed core is measured
    /// against).
    fn dispatch(&mut self, t: Micros) -> Vec<HqAction> {
        let mut acts = Vec::new();
        let mut remaining: Vec<TaskId> = Vec::new();
        let queue = std::mem::take(&mut self.queue);
        for id in queue {
            let task = &self.tasks[&id];
            if task.state != TaskState::Pending {
                continue;
            }
            let need = task.spec.cores;
            let tr = task.spec.time_request;
            let pick = self
                .workers
                .iter()
                .filter(|(_, w)| {
                    w.alive && w.cores_free >= need && w.expires_t >= t + tr
                })
                .min_by_key(|(wid, _)| **wid)
                .map(|(wid, _)| *wid);
            match pick {
                Some(wid) => {
                    let w = self.workers.get_mut(&wid).unwrap();
                    w.cores_free -= need;
                    let task = self.tasks.get_mut(&id).unwrap();
                    task.state = TaskState::Dispatched;
                    task.worker = wid;
                    self.dispatches += 1;
                    acts.push(HqAction::Timer(
                        t + self.cfg.dispatch_latency,
                        HqTimer::Dispatched(id),
                    ));
                }
                None => remaining.push(id),
            }
        }
        self.queue = remaining;
        acts.extend(self.autoalloc());
        acts
    }

    /// Expire workers: full worker-table scan, as in the seed; sorted for
    /// determinism.
    pub fn expire_workers(&mut self, t: Micros) -> Vec<HqAction> {
        let mut expired: Vec<(Micros, WorkerId)> = self
            .workers
            .iter()
            .filter(|(_, w)| w.alive && w.expires_t <= t)
            .map(|(id, w)| (w.expires_t, *id))
            .collect();
        expired.sort_unstable();
        let mut acts = Vec::new();
        for (_, wid) in expired {
            acts.extend(self.on_worker_lost(t, wid));
        }
        acts
    }

    // ---- introspection ---------------------------------------------------

    pub fn pending_tasks(&self) -> usize {
        self.queue.len()
    }

    pub fn live_workers(&self) -> usize {
        self.workers.values().filter(|w| w.alive).count()
    }

    pub fn allocs_waiting(&self) -> u32 {
        self.allocs_in_queue
    }

    /// Tasks resident in the (never-evicting) map.
    pub fn resident_tasks(&self) -> usize {
        self.tasks.len()
    }
}
