//! hqlite — a from-scratch HyperQueue-like meta-scheduler.
//!
//! The architecture matches HQ's (Böhm et al., SC21 poster): a
//! lightweight server manages its own task queue; *workers* run inside
//! allocations obtained from the native scheduler (slurmlite here) via an
//! automatic allocator; tasks are dispatched to idle workers at
//! millisecond granularity.  The paper-critical semantics are
//! implemented:
//!
//! * **time request vs time limit** — a task is only placed on a worker
//!   whose allocation has at least `time_request` remaining; the limit
//!   only kills runaways (section II.C);
//! * **automatic allocation** — `backlog`, `workers_per_alloc`,
//!   `max_worker_count` (the configuration example in section II.D);
//! * **one bulk allocation absorbs the queue wait once** — the mechanism
//!   behind the paper's three-orders-of-magnitude overhead reduction.

pub mod core;
pub mod reference;

pub use self::core::{AutoAllocConfig, HqAction, HqCore, HqTimer, TaskCore,
                     TaskId, TaskSpec, WorkerId};
pub use self::reference::ReferenceHqCore;
