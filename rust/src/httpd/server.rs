//! Threaded HTTP server with keep-alive and a bounded accept pool.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::types::{read_message, Request, Response};

/// Request handler: must be cheap to clone across worker threads.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running HTTP server.
///
/// # Shutdown contract
///
/// The handle *owns* the server: dropping it stops the accept loop and
/// joins the accept thread (in-flight connection threads drain on their
/// own within their 250 ms stop-flag poll).  Two consequences:
///
/// * **Keep the handle alive** for as long as the server must serve —
///   an unbound `serve(..)?;` expression shuts down immediately, which
///   is why the type is `#[must_use]`.
/// * **Prefer an explicit [`Server::shutdown`]** at end of scope (tests
///   especially): it makes teardown visible and joins deterministically
///   instead of relying on drop order.
#[must_use = "dropping a Server shuts it down immediately; bind it and \
              call shutdown() when done"]
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    live_conns: Arc<AtomicUsize>,
}

impl Server {
    /// Bind on 127.0.0.1 with an OS-assigned port (port 0) or a fixed one.
    pub fn serve(port: u16, handler: Handler) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let live_conns = Arc::new(AtomicUsize::new(0));

        let stop2 = stop.clone();
        let conns2 = live_conns.clone();
        let accept_thread = std::thread::Builder::new()
            .name(format!("httpd-{}", addr.port()))
            .spawn(move || {
                accept_loop(listener, handler, stop2, conns2);
            })?;

        Ok(Server { addr, stop, accept_thread: Some(accept_thread), live_conns })
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    pub fn url(&self) -> String {
        format!("http://127.0.0.1:{}", self.addr.port())
    }

    /// Number of currently open connections (used by tests/metrics).
    pub fn live_connections(&self) -> usize {
        self.live_conns.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the accept loop.  In-flight connection
    /// threads drain on their own (they observe the stop flag).
    /// Idempotent; also invoked by `Drop`, so an explicit call followed
    /// by the handle going out of scope is fine.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    handler: Handler,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicUsize>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let h = handler.clone();
                let st = stop.clone();
                let c = conns.clone();
                c.fetch_add(1, Ordering::Relaxed);
                // One thread per connection; connections are few (model
                // servers + balancer) and long-lived via keep-alive.
                let _ = std::thread::Builder::new()
                    .name("httpd-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, h, st);
                        c.fetch_sub(1, Ordering::Relaxed);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Perf pass: 500us accept poll (was 2 ms) — new
                // connections are rare once the balancer pools them, but
                // registration latency still benefits.
                std::thread::sleep(Duration::from_micros(500));
            }
            Err(_) => break,
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    handler: Handler,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // Periodic read timeout so the connection thread can observe `stop`.
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let msg = match read_message(&mut reader) {
            Ok(Some(m)) => m,
            Ok(None) => return Ok(()), // peer closed
            Err(e) => {
                // Timeout: loop to re-check stop; anything else: drop conn.
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                return Err(e);
            }
        };
        let (start, headers, body) = msg;
        let mut parts = start.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();
        if method.is_empty() || path.is_empty() {
            return Err(anyhow!("malformed request line: {start}"));
        }
        let keep_alive = headers
            .get("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);

        let req = Request { method, path, headers, body };
        let resp = handler(&req);
        resp.write_to(keep_alive, &mut writer)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::HttpClient;

    fn echo_server() -> Server {
        Server::serve(
            0,
            Arc::new(|req: &Request| {
                if req.path == "/echo" {
                    Response::ok_json(
                        String::from_utf8_lossy(&req.body).to_string(),
                    )
                } else if req.path == "/hello" {
                    Response::text(200, "world")
                } else {
                    Response::not_found()
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn get_and_post() {
        let mut srv = echo_server();
        let mut c = HttpClient::connect(&srv.url()).unwrap();
        let r = c.request(&Request::get("/hello")).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body_str().unwrap(), "world");
        let r = c.request(&Request::post("/echo", "{\"x\":3}")).unwrap();
        assert_eq!(r.body_str().unwrap(), "{\"x\":3}");
        srv.shutdown();
    }

    #[test]
    fn keep_alive_reuses_connection() {
        let mut srv = echo_server();
        let mut c = HttpClient::connect(&srv.url()).unwrap();
        for i in 0..20 {
            let body = format!("{{\"i\":{i}}}");
            let r = c.request(&Request::post("/echo", &body)).unwrap();
            assert_eq!(r.body_str().unwrap(), body);
        }
        // 20 requests over one connection.
        assert!(srv.live_connections() <= 1);
        srv.shutdown();
    }

    #[test]
    fn not_found() {
        let mut srv = echo_server();
        let mut c = HttpClient::connect(&srv.url()).unwrap();
        let r = c.request(&Request::get("/nope")).unwrap();
        assert_eq!(r.status, 404);
        srv.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let mut srv = echo_server();
        let url = srv.url();
        let mut threads = Vec::new();
        for t in 0..8 {
            let url = url.clone();
            threads.push(std::thread::spawn(move || {
                let mut c = HttpClient::connect(&url).unwrap();
                for i in 0..10 {
                    let body = format!("{{\"t\":{t},\"i\":{i}}}");
                    let r = c.request(&Request::post("/echo", &body)).unwrap();
                    assert_eq!(r.body_str().unwrap(), body);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        srv.shutdown();
    }

    #[test]
    fn large_body_roundtrip() {
        let mut srv = echo_server();
        let mut c = HttpClient::connect(&srv.url()).unwrap();
        let big = "x".repeat(2 * 1024 * 1024);
        let r = c.request(&Request::post("/echo", &big)).unwrap();
        assert_eq!(r.body.len(), big.len());
        srv.shutdown();
    }

    #[test]
    fn drop_shuts_down() {
        // The ownership contract: the handle going out of scope stops
        // the server (no leaked accept thread, no stolen port).
        let url = {
            let srv = echo_server();
            srv.url()
        };
        std::thread::sleep(Duration::from_millis(50));
        assert!(HttpClient::connect(&url)
            .and_then(|mut c| c.request(&Request::get("/hello")))
            .is_err());
    }

    #[test]
    fn shutdown_stops_accepting() {
        let mut srv = echo_server();
        let url = srv.url();
        srv.shutdown();
        // New connections should fail (listener dropped with the loop).
        std::thread::sleep(Duration::from_millis(50));
        assert!(HttpClient::connect(&url)
            .and_then(|mut c| c.request(&Request::get("/hello")))
            .is_err());
    }
}
