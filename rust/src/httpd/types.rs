//! HTTP message types and the shared read/parse path.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Context, Result};

/// Incoming request (server side) / outgoing request (client side).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.into(),
            headers: HashMap::new(),
            body: Vec::new(),
        }
    }

    pub fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.into(),
            headers: HashMap::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body not utf-8")
    }

    /// Serialise onto the wire.
    pub fn write_to(&self, host: &str, w: &mut impl std::io::Write) -> Result<()> {
        write!(w, "{} {} HTTP/1.1\r\n", self.method, self.path)?;
        write!(w, "host: {host}\r\n")?;
        write!(w, "content-length: {}\r\n", self.body.len())?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

/// HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn ok_json(json: String) -> Response {
        let mut headers = HashMap::new();
        headers.insert("content-type".into(), "application/json".into());
        Response { status: 200, headers, body: json.into_bytes() }
    }

    pub fn text(status: u16, body: &str) -> Response {
        let mut headers = HashMap::new();
        headers.insert("content-type".into(), "text/plain".into());
        Response { status, headers, body: body.as_bytes().to_vec() }
    }

    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }

    pub fn error(msg: &str) -> Response {
        Response::text(500, msg)
    }

    /// 503 with a `Retry-After` hint — the balancer's backpressure
    /// signal when a per-model queue is full.
    pub fn unavailable(msg: &str, retry_after_secs: u32) -> Response {
        Response::text(503, msg)
            .with_header("retry-after", &retry_after_secs.to_string())
    }

    /// Builder-style header attachment.
    pub fn with_header(mut self, key: &str, value: &str) -> Response {
        self.headers.insert(key.to_ascii_lowercase(), value.to_string());
        self
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("response body not utf-8")
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        }
    }

    pub fn write_to(&self, keep_alive: bool, w: &mut impl std::io::Write) -> Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, self.reason())?;
        write!(w, "content-length: {}\r\n", self.body.len())?;
        write!(
            w,
            "connection: {}\r\n",
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

/// Read one HTTP message (request or response) from a buffered stream.
/// Returns (start_line, headers, body); None on clean EOF before any byte.
pub fn read_message(
    r: &mut BufReader<TcpStream>,
) -> Result<Option<(String, HashMap<String, String>, Vec<u8>)>> {
    let mut start = String::new();
    let n = r.read_line(&mut start)?;
    if n == 0 {
        return Ok(None); // connection closed between messages
    }
    let start = start.trim_end().to_string();
    if start.is_empty() {
        bail!("empty start line");
    }

    let mut headers = HashMap::new();
    loop {
        let mut line = String::new();
        let n = r.read_line(&mut line)?;
        if n == 0 {
            bail!("eof in headers");
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header: {line}"))?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse())
        .transpose()
        .context("bad content-length")?
        .unwrap_or(0);
    // Bound body size: largest legitimate payload is an eigen-large matrix
    // (~a few MB of JSON); 64 MiB is a safety ceiling, not a target.
    if len > 64 * 1024 * 1024 {
        bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("short body")?;
    Ok(Some((start, headers, body)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_serialises() {
        let rq = Request::post("/Evaluate", "{\"a\":1}");
        let mut buf = Vec::new();
        rq.write_to("h", &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("POST /Evaluate HTTP/1.1\r\n"));
        assert!(s.contains("content-length: 7\r\n"));
        assert!(s.ends_with("\r\n\r\n{\"a\":1}"));
    }

    #[test]
    fn response_serialises() {
        let rs = Response::ok_json("[1]".into());
        let mut buf = Vec::new();
        rs.write_to(true, &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("connection: keep-alive"));
        assert!(s.ends_with("[1]"));
    }

    #[test]
    fn status_reasons() {
        assert_eq!(Response::not_found().status, 404);
        assert_eq!(Response::error("x").status, 500);
    }

    #[test]
    fn unavailable_carries_retry_after() {
        let r = Response::unavailable("queue full", 2);
        assert_eq!(r.status, 503);
        assert_eq!(r.headers.get("retry-after").map(|s| s.as_str()),
                   Some("2"));
        let mut buf = Vec::new();
        r.write_to(true, &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.contains("retry-after: 2\r\n"));
    }
}
