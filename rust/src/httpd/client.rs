//! HTTP client with persistent (keep-alive) connections and reconnect.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::types::{read_message, Request, Response};

/// A client bound to one `http://host:port` endpoint, reusing a single
/// keep-alive connection and transparently reconnecting once on failure
/// (the server may have restarted — the balancer relies on this).
pub struct HttpClient {
    host: String,
    port: u16,
    conn: Option<Conn>,
    /// Per-request timeout; evaluation calls can be long (gs2 chunks), so
    /// the default is generous.
    pub timeout: Duration,
}

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl HttpClient {
    /// Parse `http://host:port` (path ignored) and prepare a client; the
    /// TCP connection is opened lazily on first request.
    pub fn connect(url: &str) -> Result<HttpClient> {
        let (host, port) = parse_url(url)?;
        let mut c = HttpClient {
            host,
            port,
            conn: None,
            timeout: Duration::from_secs(600),
        };
        c.ensure_conn()?; // fail fast on unreachable endpoints
        Ok(c)
    }

    pub fn endpoint(&self) -> String {
        format!("http://{}:{}", self.host, self.port)
    }

    fn ensure_conn(&mut self) -> Result<()> {
        if self.conn.is_none() {
            let stream = TcpStream::connect((self.host.as_str(), self.port))
                .with_context(|| {
                    format!("connect {}:{}", self.host, self.port)
                })?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.timeout))?;
            let writer = stream.try_clone()?;
            self.conn = Some(Conn { writer, reader: BufReader::new(stream) });
        }
        Ok(())
    }

    /// Issue a request; retries once on a broken connection.
    pub fn request(&mut self, req: &Request) -> Result<Response> {
        match self.try_request(req) {
            Ok(r) => Ok(r),
            Err(_first) => {
                // Reconnect once: the peer may have closed an idle
                // keep-alive connection or restarted.
                self.conn = None;
                self.ensure_conn()?;
                self.try_request(req)
            }
        }
    }

    fn try_request(&mut self, req: &Request) -> Result<Response> {
        self.ensure_conn()?;
        let conn = self.conn.as_mut().unwrap();
        let host = format!("{}:{}", self.host, self.port);
        if let Err(e) = req.write_to(&host, &mut conn.writer) {
            self.conn = None;
            return Err(e);
        }
        match read_message(&mut conn.reader) {
            Ok(Some((start, headers, body))) => {
                let status = parse_status(&start)?;
                let keep = headers
                    .get("connection")
                    .map(|v| !v.eq_ignore_ascii_case("close"))
                    .unwrap_or(true);
                if !keep {
                    self.conn = None;
                }
                Ok(Response { status, headers, body })
            }
            Ok(None) => {
                self.conn = None;
                bail!("server closed connection");
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }
}

fn parse_url(url: &str) -> Result<(String, u16)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| anyhow!("only http:// urls supported: {url}"))?;
    let hostport = rest.split('/').next().unwrap_or(rest);
    let (host, port) = hostport
        .split_once(':')
        .ok_or_else(|| anyhow!("missing port in url: {url}"))?;
    Ok((host.to_string(), port.parse().context("bad port")?))
}

fn parse_status(start: &str) -> Result<u16> {
    // "HTTP/1.1 200 OK"
    start
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line: {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_urls() {
        assert_eq!(parse_url("http://127.0.0.1:8080").unwrap(),
                   ("127.0.0.1".to_string(), 8080));
        assert_eq!(parse_url("http://h:1/path/x").unwrap(),
                   ("h".to_string(), 1));
        assert!(parse_url("https://h:1").is_err());
        assert!(parse_url("http://h").is_err());
    }

    #[test]
    fn parses_status_lines() {
        assert_eq!(parse_status("HTTP/1.1 200 OK").unwrap(), 200);
        assert_eq!(parse_status("HTTP/1.1 503 Service Unavailable").unwrap(),
                   503);
        assert!(parse_status("garbage").is_err());
    }

    #[test]
    fn connect_refused_errors() {
        // Port 1 is essentially never listening.
        assert!(HttpClient::connect("http://127.0.0.1:1").is_err());
    }
}
