//! Minimal HTTP/1.1 substrate over `std::net`: threaded server + client.
//!
//! This carries the UM-Bridge protocol (JSON bodies, `Content-Length`
//! framing, keep-alive connections).  Scope is deliberately what the
//! system needs — GET/POST, persistent connections, a bounded worker
//! pool — implemented carefully rather than generally.

mod client;
mod server;
mod types;

pub use client::HttpClient;
pub use server::{Handler, Server};
pub use types::{read_message, Request, Response};
