//! Minimal HTTP/1.1 substrate over `std::net`: threaded server + client.
//!
//! This carries the UM-Bridge protocol (JSON bodies, `Content-Length`
//! framing, keep-alive connections).  Scope is deliberately what the
//! system needs — GET/POST, persistent connections, a bounded worker
//! pool — implemented carefully rather than generally.
//!
//! # Lifecycle
//!
//! [`Server`] is an owning, `#[must_use]` handle: one accept thread per
//! server, one thread per live connection, all signalled through a
//! shared stop flag.  Dropping the handle (or calling
//! [`Server::shutdown`]) stops the accept loop and joins it; connection
//! threads observe the flag within their 250 ms read-timeout poll and
//! drain on their own.  See the `Server` docs for the full shutdown
//! contract.  [`HttpClient`] is a plain blocking keep-alive connection
//! and needs no teardown beyond drop.

mod client;
mod server;
mod types;

pub use client::HttpClient;
pub use server::{Handler, Server};
pub use types::{read_message, Request, Response};
