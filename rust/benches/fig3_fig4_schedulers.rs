//! Regenerates paper Figures 3 and 4: SLURM vs HQ boxplots of makespan /
//! CPU time / scheduler overhead (Fig 3) and SLR (Fig 4) for the four
//! applications at queue depths 2 and 10 — 100 evaluations per cell on
//! the Hamilton8-profile sim plane — plus a third `steal` series (the
//! work-stealing scheduler behind the same `SchedulerCore` seam), the
//! kind of policy ablation the pluggable scheduler API makes one-line.
//!
//! Also prints the paper's headline checks: overhead reduction factor
//! (up to three orders of magnitude), GS2 mean-makespan reduction
//! (paper: ~38%), and the eigen-100@2 speed-up (paper: ~3x).
//!
//! Output: ASCII panels + CSV under results/.  Set
//! `UQSCHED_FIG3_WORKSTEAL=0` to drop the extra series and regenerate
//! the two-scheduler paper figures exactly.

use std::path::Path;

use uqsched::experiments::{run_naive_slurm, run_umbridge_hq,
                           run_umbridge_worksteal, Config};
use uqsched::metrics::report::Panel;
use uqsched::metrics::{BoxStats, Experiment};
use uqsched::workload::App;

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

fn median(v: &[f64]) -> f64 {
    BoxStats::from(v).median
}

fn main() {
    let t0 = std::time::Instant::now();
    let results = Path::new("results");
    let n_evals: u64 = std::env::var("UQSCHED_EVALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let with_worksteal = std::env::var("UQSCHED_FIG3_WORKSTEAL")
        .map(|v| v != "0")
        .unwrap_or(true);

    println!("=== Fig 3 + Fig 4 harness: 4 apps x {{2,10}} jobs x \
              {{SLURM, HQ{}}} x {n_evals} evaluations ===\n",
             if with_worksteal { ", steal" } else { "" });

    let mut headline: Vec<String> = Vec::new();

    for queue_depth in [2usize, 10] {
        let mut p_makespan = Panel::new(
            &format!("Fig 3 makespan, {queue_depth} jobs"), "s", true);
        let mut p_cpu = Panel::new(
            &format!("Fig 3 CPU time, {queue_depth} jobs"), "s", true);
        let mut p_over = Panel::new(
            &format!("Fig 3 scheduler overhead, {queue_depth} jobs"), "s",
            true);
        let mut p_slr = Panel::new(
            &format!("Fig 4 SLR, {queue_depth} jobs"), "ratio", true);

        for app in App::all() {
            let mut cfg = Config::paper(app, queue_depth,
                                        0xF16_3 + queue_depth as u64);
            cfg.n_evals = n_evals;
            let s = run_naive_slurm(&cfg);
            let h = run_umbridge_hq(&cfg);

            p_makespan.push(app.label(), "SLURM", s.makespans_sec());
            p_makespan.push(app.label(), "HQ", h.makespans_sec());
            p_cpu.push(app.label(), "SLURM", s.cpus_sec());
            p_cpu.push(app.label(), "HQ", h.cpus_sec());
            p_over.push(app.label(), "SLURM", s.overheads_sec());
            p_over.push(app.label(), "HQ", h.overheads_sec());
            p_slr.push(app.label(), "SLURM", s.slrs());
            p_slr.push(app.label(), "HQ", h.slrs());
            if with_worksteal {
                let w = run_umbridge_worksteal(&cfg);
                p_makespan.push(app.label(), "steal", w.makespans_sec());
                p_cpu.push(app.label(), "steal", w.cpus_sec());
                p_over.push(app.label(), "steal", w.overheads_sec());
                p_slr.push(app.label(), "steal", w.slrs());
            }

            headline_checks(&mut headline, app, queue_depth, &s, &h);
        }

        for (panel, stem) in [
            (&p_makespan, format!("fig3_makespan_q{queue_depth}")),
            (&p_cpu, format!("fig3_cpu_q{queue_depth}")),
            (&p_over, format!("fig3_overhead_q{queue_depth}")),
            (&p_slr, format!("fig4_slr_q{queue_depth}")),
        ] {
            println!("{}", panel.render());
            panel.save(results, &stem).expect("save csv");
        }
    }

    println!("=== headline claims (paper section V) ===");
    let mut best_factor = 0f64;
    for h in &headline {
        println!("  {h}");
        if let Some(f) = h.split("-> ").nth(1)
            .and_then(|t| t.split('x').next())
            .and_then(|t| t.trim().parse::<f64>().ok())
        {
            best_factor = best_factor.max(f);
        }
    }
    println!("  max overhead reduction across cells: {best_factor:.0}x {}",
             if best_factor >= 1000.0 {
                 "(>= 3 orders of magnitude, matches the paper's 'up to')"
             } else {
                 "(CHECK: below 3 orders)"
             });
    println!("\nfig3_fig4 harness done in {:.1?} (CSV in results/)",
             t0.elapsed());
}

fn headline_checks(out: &mut Vec<String>, app: App, qd: usize,
                   s: &Experiment, h: &Experiment) {
    // Overhead reduction: median per-job scheduler overhead.  HQ's
    // steady-state overhead is ms-scale vs SLURM's tens of seconds.
    let s_over = median(&s.overheads_sec()).max(1e-6);
    // Exclude the first-allocation outlier from HQ's median (it is the
    // documented dominant overhead; the paper reports it separately).
    let mut h_over: Vec<f64> = h.overheads_sec();
    h_over.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let h_med = h_over[h_over.len() / 2].max(1e-6);
    let factor = s_over / h_med;
    out.push(format!(
        "{} q{qd}: per-job overhead SLURM {:.2}s vs HQ {:.4}s -> {:.0}x \
         reduction",
        app.label(), s_over, h_med, factor,
    ));

    if app == App::Gs2 {
        let ms = mean(&s.makespans_sec());
        let mh = mean(&h.makespans_sec());
        let red = 100.0 * (1.0 - mh / ms);
        out.push(format!(
            "gs2 q{qd}: mean makespan SLURM {:.0}s vs HQ {:.0}s -> {red:.0}% \
             reduction (paper: ~38%)",
            ms, mh
        ));
    }
    if app == App::Eigen100 && qd == 2 {
        let ms = mean(&s.makespans_sec());
        let mh = mean(&h.makespans_sec());
        out.push(format!(
            "eigen-100 q2: mean makespan SLURM {:.1}s vs HQ {:.1}s -> \
             {:.1}x quicker (paper: ~3x)",
            ms, mh, ms / mh
        ));
        // CPU-time penalty on the fastest tasks (server init ~1 s).
        let cs = mean(&s.cpus_sec());
        let ch = mean(&h.cpus_sec());
        out.push(format!(
            "eigen-100 q2: mean CPU SLURM {cs:.2}s vs HQ {ch:.2}s \
             (HQ pays the ~1s server init; paper observes the same sign \
             when prolog < init)"
        ));
    }
}
