//! Regenerates paper Appendix A (Figures 5 and 6): naive SLURM vs the
//! UM-Bridge SLURM backend, GS2 only, queue depths 2 and 10.
//!
//! Expected shape (paper): the SLURM backend submits individual jobs
//! without changing the scheduling mechanism, so there are no gains over
//! the baseline — similar makespan/overhead, slightly higher CPU time
//! from the in-job model-server start-up.

use std::path::Path;

use uqsched::experiments::{run_naive_slurm, run_umbridge_slurm, Config};
use uqsched::metrics::report::Panel;
use uqsched::workload::App;

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

fn main() {
    let t0 = std::time::Instant::now();
    let results = Path::new("results");
    let n_evals: u64 = std::env::var("UQSCHED_EVALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);

    println!("=== Fig 5 + Fig 6 harness: gs2 x {{2,10}} jobs x \
              {{SLURM, UM-Bridge SLURM}} x {n_evals} evaluations ===\n");

    for queue_depth in [2usize, 10] {
        let mut cfg = Config::paper(App::Gs2, queue_depth,
                                    0xF56 + queue_depth as u64);
        cfg.n_evals = n_evals;
        let s = run_naive_slurm(&cfg);
        let u = run_umbridge_slurm(&cfg);

        let mut p_mk = Panel::new(
            &format!("Fig 6 makespan, {queue_depth} jobs"), "s", false);
        let mut p_cpu = Panel::new(
            &format!("Fig 6 CPU time, {queue_depth} jobs"), "s", false);
        let mut p_ov = Panel::new(
            &format!("Fig 6 scheduler overhead, {queue_depth} jobs"), "s",
            true);
        let mut p_slr = Panel::new(
            &format!("Fig 5 SLR, {queue_depth} jobs"), "ratio", false);

        p_mk.push("gs2", "SLURM", s.makespans_sec());
        p_mk.push("gs2", "UM-SLURM", u.makespans_sec());
        p_cpu.push("gs2", "SLURM", s.cpus_sec());
        p_cpu.push("gs2", "UM-SLURM", u.cpus_sec());
        p_ov.push("gs2", "SLURM", s.overheads_sec());
        p_ov.push("gs2", "UM-SLURM", u.overheads_sec());
        p_slr.push("gs2", "SLURM", s.slrs());
        p_slr.push("gs2", "UM-SLURM", u.slrs());

        for (panel, stem) in [
            (&p_mk, format!("fig6_makespan_q{queue_depth}")),
            (&p_cpu, format!("fig6_cpu_q{queue_depth}")),
            (&p_ov, format!("fig6_overhead_q{queue_depth}")),
            (&p_slr, format!("fig5_slr_q{queue_depth}")),
        ] {
            println!("{}", panel.render());
            panel.save(results, &stem).expect("save csv");
        }

        let ms = mean(&s.makespans_sec());
        let mu = mean(&u.makespans_sec());
        println!(
            "check q{queue_depth}: mean makespan SLURM {ms:.0}s vs UM-Bridge \
             SLURM {mu:.0}s -> {} (paper: no performance gains)\n",
            if mu >= ms * 0.95 { "no gain, OK" } else { "CHECK" }
        );
    }
    println!("fig5_fig6 harness done in {:.1?} (CSV in results/)",
             t0.elapsed());
}
