//! Ablation (paper section VI): per-job model servers — the measured
//! configuration, where "the cost of initialising model servers per job
//! is a bottleneck" — vs the paper's proposed **persistent servers**
//! (our extension, implemented in the balancer).  Measured on the live
//! stack: real HTTP, real PJRT evaluations, scheduler constants
//! compressed by time-scale 2000 (1 paper-second ~ 0.5 ms).

use std::sync::Arc;
use std::time::Instant;

use uqsched::coordinator::start_live;
use uqsched::json::Value;
use uqsched::metrics::BoxStats;
use uqsched::models;
use uqsched::runtime::Engine;
use uqsched::umbridge::HttpModel;
use uqsched::workload::lhs;

fn run(eng: Arc<Engine>, persistent: bool, evals: usize) -> Vec<f64> {
    let stack = start_live(eng, &[models::GP_NAME], "hq", 2,
                           2000.0, persistent,
                           uqsched::sched::LivePolicy::Fcfs)
        .expect("live stack");
    let mut client = HttpModel::connect(&stack.balancer.url(),
                                        models::GP_NAME)
        .expect("client");
    let cfg = Value::Obj(Default::default());
    let points = lhs(evals, 31);
    let mut makespans = Vec::with_capacity(evals);
    for p in &points {
        let t0 = Instant::now();
        client.evaluate(&[p.to_vec()], &cfg).expect("evaluate");
        makespans.push(t0.elapsed().as_secs_f64() * 1e3); // ms
    }
    makespans
}

fn main() {
    let evals: usize = std::env::var("UQSCHED_EVALS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    println!("=== ablation: per-job vs persistent model servers \
              (GP, hq backend, {evals} evaluations, live plane) ===");
    let eng = Arc::new(Engine::from_default_dir().expect("engine"));
    eng.warmup(&["gp_predict_b16"]).expect("warmup");

    let per_job = run(eng.clone(), false, evals);
    let persistent = run(eng.clone(), true, evals);

    println!("per-job servers    [ms]: {}", BoxStats::from(&per_job).row());
    println!("persistent servers [ms]: {}", BoxStats::from(&persistent).row());
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let mj = mean(&per_job);
    let mp = mean(&persistent);
    println!(
        "\nmean per-eval makespan: per-job {mj:.2} ms vs persistent \
         {mp:.2} ms -> {:.1}x\n\
         (the paper's section-VI prediction: removing the per-job server \
         init removes the fast-job bottleneck — confirmed {})",
        mj / mp,
        if mp < mj { "(persistent wins)" } else { "(CHECK)" }
    );
    std::process::exit(0);
}
