//! Regenerates the paper's tables and Fig 2:
//!   Table I   — feature matrix of the four configurations (from
//!               code-level capability flags)
//!   Table II  — the GS2 input-parameter space
//!   Table III — per-benchmark resource requests
//!   Fig 2     — GP prior draws + posterior mean / 95% CI on toy data
//!               (results/fig2_gp_posterior.csv), from the pure-Rust GP.

use std::path::Path;

use uqsched::clock::MIN;
use uqsched::models::gp_ref;
use uqsched::workload::{scenario, App};

fn main() {
    let results = Path::new("results");
    std::fs::create_dir_all(results).expect("results dir");

    table1();
    table2();
    table3();
    fig2(results);
    println!("tables harness done (results/fig2_gp_posterior.csv written)");
}

fn table1() {
    // (feature, kubernetes, hq, umbridge-slurm, slurm-only)
    let rows: [(&str, [&str; 4]); 6] = [
        ("Containerisation", ["Required", "Optional", "Optional", "Optional"]),
        ("Multi-node support", ["yes", "experimental", "yes", "yes"]),
        ("Concurrent jobs", ["yes", "yes", "yes", "yes"]),
        ("Dependent tasks", ["experimental", "yes (Python API)", "yes", "yes"]),
        ("Flexible job times", ["no", "yes", "no", "no"]),
        ("Scheduler", ["HA Proxy", "HQ", "SLURM", "SLURM"]),
    ];
    println!("=== Table I: load-balancer feature comparison ===");
    println!("{:<22} {:>14} {:>18} {:>16} {:>12}", "",
             "UM-Bridge K8s", "UM-Bridge HQ", "UM-Bridge SLURM",
             "SLURM only");
    for (feat, cells) in rows {
        println!("{feat:<22} {:>14} {:>18} {:>16} {:>12}",
                 cells[0], cells[1], cells[2], cells[3]);
    }
    println!();
}

fn table2() {
    println!("=== Table II: GS2 input parameters (LHS ranges) ===");
    let names = [
        "Safety factor",
        "Magnetic shear",
        "Electron density gradient",
        "Electron temperature gradient",
        "Plasma beta",
        "Electron-ion collision frequency",
        "Bi-normal mode wavelength",
    ];
    let lo = [2.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.0];
    let hi = [9.0, 5.0, 10.0, 6.0, 0.3, 0.1, 1.0];
    println!("{:<34} {:>8} {:>8}", "Input name", "Min", "Max");
    for i in 0..7 {
        println!("{:<34} {:>8} {:>8}", names[i], lo[i], hi[i]);
    }
    println!();
}

fn table3() {
    println!("=== Table III: resource requests per benchmark ===");
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}",
             "", "eigen-100", "eigen-5000", "gs2", "GP");
    let s: Vec<_> = App::all().iter().map(|&a| scenario(a)).collect();
    let m = |v: u64| (v / MIN).to_string();
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}", "SLURM alloc time (mins)",
             m(s[0].slurm_time), m(s[1].slurm_time), m(s[2].slurm_time),
             m(s[3].slurm_time));
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}", "HQ alloc time (mins)",
             m(s[0].hq_alloc_time), m(s[1].hq_alloc_time),
             m(s[2].hq_alloc_time), m(s[3].hq_alloc_time));
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}", "HQ job time request (mins)",
             m(s[0].hq_time_request), m(s[1].hq_time_request),
             m(s[2].hq_time_request), m(s[3].hq_time_request));
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}", "HQ job time limit (mins)",
             m(s[0].hq_time_limit), m(s[1].hq_time_limit),
             m(s[2].hq_time_limit), m(s[3].hq_time_limit));
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}", "SLURM/HQ CPUs",
             s[0].cpus, s[1].cpus, s[2].cpus, s[3].cpus);
    println!("{:<34} {:>10} {:>11} {:>6} {:>6}", "SLURM/HQ RAM (GB)",
             s[0].ram_gb, s[1].ram_gb, s[2].ram_gb, s[3].ram_gb);
    println!();
}

fn fig2(results: &Path) {
    println!("=== Fig 2: GP posterior on toy data (pure-Rust GP) ===");
    let (gp, grid) = gp_ref::fig2_data();
    let (mean, var) = gp.predict(&grid);
    let draws = gp.sample_posterior(&grid, 3, 20250710);
    let mut csv = String::from("x,mean,ci_lo,ci_hi,draw1,draw2,draw3\n");
    for (i, &x) in grid.iter().enumerate() {
        let sd = var[i].sqrt();
        csv.push_str(&format!(
            "{x},{},{},{},{},{},{}\n",
            mean[i],
            mean[i] - 1.96 * sd,
            mean[i] + 1.96 * sd,
            draws[0][i],
            draws[1][i],
            draws[2][i]
        ));
    }
    std::fs::write(results.join("fig2_gp_posterior.csv"), csv)
        .expect("write fig2 csv");
    // Tiny ASCII rendition: mean with CI width markers at a few points.
    for i in (0..grid.len()).step_by(12) {
        let sd = var[i].sqrt();
        println!("  x={:+.1}  mean={:+.3}  ±{:.3}", grid[i], mean[i],
                 1.96 * sd);
    }
    println!("  training points at x = {:?}", gp.xs);
    println!();
}
