//! Million-task scale benchmark for all five scheduler cores.
//!
//! Drives the indexed `SlurmCore`/`HqCore` (and their seed-semantics
//! reference twins) plus the partitioned `WorkStealCore`, the
//! deadline-EDF `EdfCore` and the moldable `GangCore` through
//! synthetic task streams at several
//! queue depths, printing tasks/s and peak resident map sizes and
//! emitting `BENCH_scale.json` so the perf trajectory is tracked across
//! PRs.
//!
//! Run with:
//!
//! ```text
//! cargo bench --bench scale
//! ```
//!
//! Environment knobs:
//!   SCALE_TASKS           max task count for the indexed cores  (default 1_000_000)
//!   SCALE_NAIVE_TASKS     max task count for the naive baseline (default 100_000)
//!   SCALE_CAMPAIGN_TASKS  campaign-mode task count, 0 disables  (default 100_000)
//!   SCALE_OUT             output path                           (default BENCH_scale.json)
//!   UQSCHED_ALLOC_TASKS   N for the marginal alloc profile      (default 20_000)
//!   UQSCHED_ALLOC_ROWS=1  hard-assert the allocs/task ceiling (CI smoke)
//!   UQSCHED_MIN_TASKS_PER_S  opt-in throughput floor for indexed rows
//!
//! The workload is deliberately UQ-shaped: a stream of identical small
//! tasks (the paper's "thousands or even millions of similar tasks"),
//! with a bounded number kept in flight ("queue depth") — depth 0 means
//! submit everything up front, the worst case for the pending queue.
//!
//! Both implementations of a core run through the SAME generic driver
//! (statically dispatched trait shims), so the indexed-vs-naive speedup
//! can never be skewed by divergent driver loops.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use uqsched::campaign::{self, AdaptiveBayes, CampaignConfig, Mlda,
                        MldaLevel, PoissonBurst, SlurmMode, StageInOut,
                        Submitter};
use uqsched::clock::{Des, Micros, MS, SEC};
use uqsched::cluster::{ClusterSpec, JobRequest, OverheadModel};
use uqsched::workload::App;
use uqsched::hqlite::{AutoAllocConfig, HqAction, HqCore, HqTimer,
                      ReferenceHqCore, TaskCore, TaskSpec};
use uqsched::json::Value;
use uqsched::sched::{EdfCore, FaultSpec, GangCore, WorkStealCore};
use uqsched::slurmlite::core::{Action, BatchCore, SlurmCore, Timer,
                               USER_EXPERIMENT};
use uqsched::slurmlite::ReferenceSlurmCore;

// ---------------------------------------------------------------------------
// Counting allocator: every heap allocation in this bench binary ticks
// a call counter and a live-bytes watermark, so the slab-arena hot path
// can be held to an allocations-per-task budget.  The profile uses the
// marginal two-size method — allocs(2N) - allocs(N), over N — so
// one-time setup (core construction, pool warm-up, container growth to
// the depth-bounded working set) cancels and only the steady-state
// drain cost remains.  The instrumented path costs two relaxed atomic
// ops per allocation; the throughput rows allocate (by design) almost
// never, so they are unaffected.
// ---------------------------------------------------------------------------

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicUsize = AtomicUsize::new(0);
static PEAK_BYTES: AtomicUsize = AtomicUsize::new(0);

#[inline]
fn note_grow(by: usize) {
    let live = LIVE_BYTES.fetch_add(by, Ordering::Relaxed) + by;
    PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
            note_grow(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE_BYTES.fetch_sub(layout.size(), Ordering::Relaxed);
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
            if new_size >= layout.size() {
                note_grow(new_size - layout.size());
            } else {
                LIVE_BYTES.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC_METER: CountingAlloc = CountingAlloc;

/// One measurement row.
struct Row {
    core: &'static str,
    imp: &'static str,
    tasks: u64,
    depth: usize,
    wall_s: f64,
    tasks_per_s: f64,
    peak_resident: usize,
    des_events: u64,
}

impl Row {
    fn print(&self) {
        println!(
            "  {:<6} {:<8} {:>9} tasks  depth {:>8}  {:>8.3} s  {:>12.0} tasks/s  peak resident {:>8}  {:>9} events",
            self.core, self.imp, self.tasks,
            if self.depth == 0 { "all".to_string() } else { self.depth.to_string() },
            self.wall_s, self.tasks_per_s, self.peak_resident, self.des_events,
        );
    }

    fn json(&self) -> Value {
        Value::obj(vec![
            ("core", Value::str(self.core)),
            ("impl", Value::str(self.imp)),
            ("tasks", Value::num(self.tasks as f64)),
            ("depth", Value::num(self.depth as f64)),
            ("wall_s", Value::num(self.wall_s)),
            ("tasks_per_s", Value::num(self.tasks_per_s)),
            ("peak_resident", Value::num(self.peak_resident as f64)),
            ("des_events", Value::num(self.des_events as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// slurmlite: one generic driver over both implementations
// ---------------------------------------------------------------------------

const SLURM_DUR: Micros = 10 * SEC;
const SLURM_REQ_TIME: Micros = 3600 * SEC;

#[derive(Debug)]
enum SEv {
    Timer(Timer),
    Submit,
    Finish(u64),
}

fn slurm_req() -> JobRequest {
    JobRequest::new(1, 2, SLURM_REQ_TIME)
}

/// Driver shim: the indexed core appends via its `*_into` sink API, the
/// reference extends from its allocating API (that allocation cost is
/// part of what the baseline measures).
trait SlurmDriver {
    fn drv_boot(&mut self, out: &mut Vec<Action>);
    fn drv_timer(&mut self, t: Micros, tm: Timer, out: &mut Vec<Action>);
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<Action>);
    fn drv_finish(&mut self, t: Micros, id: u64, out: &mut Vec<Action>);
    fn drv_resident(&self) -> usize;
}

impl SlurmDriver for SlurmCore {
    fn drv_boot(&mut self, out: &mut Vec<Action>) {
        out.extend(self.bootstrap(0));
    }
    fn drv_timer(&mut self, t: Micros, tm: Timer, out: &mut Vec<Action>) {
        self.on_timer_into(t, tm, out);
    }
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<Action>) {
        self.submit_into(t, USER_EXPERIMENT, tag, slurm_req(), out);
    }
    fn drv_finish(&mut self, t: Micros, id: u64, out: &mut Vec<Action>) {
        self.on_finish_into(t, id, out);
    }
    fn drv_resident(&self) -> usize {
        self.resident_jobs()
    }
}

impl SlurmDriver for ReferenceSlurmCore {
    fn drv_boot(&mut self, out: &mut Vec<Action>) {
        out.extend(self.bootstrap(0));
    }
    fn drv_timer(&mut self, t: Micros, tm: Timer, out: &mut Vec<Action>) {
        out.extend(self.on_timer(t, tm));
    }
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<Action>) {
        let (_, acts) = self.submit(t, USER_EXPERIMENT, tag, slurm_req());
        out.extend(acts);
    }
    fn drv_finish(&mut self, t: Micros, id: u64, out: &mut Vec<Action>) {
        out.extend(self.on_finish(t, id));
    }
    fn drv_resident(&self) -> usize {
        self.resident_jobs()
    }
}

/// `depth == 0`: everything submitted up front.
fn run_slurm<C: SlurmDriver>(
    core: &mut C,
    imp: &'static str,
    n: u64,
    depth: usize,
) -> Row {
    let mut des: Des<SEv> = Des::new();
    let t0 = Instant::now();
    let mut acts: Vec<Action> = Vec::new();
    core.drv_boot(&mut acts);
    for a in acts.drain(..) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, SEv::Timer(tm));
        }
    }
    let window = if depth == 0 { n } else { depth.min(n as usize) as u64 };
    for _ in 0..window {
        des.schedule(0, SEv::Submit);
    }
    let mut submitted: u64 = 0;
    let mut completed: u64 = 0;
    let mut peak_resident = 0usize;
    while let Some((t, ev)) = des.pop() {
        acts.clear();
        match ev {
            SEv::Timer(tm) => core.drv_timer(t, tm, &mut acts),
            SEv::Submit => {
                if submitted < n {
                    let tag = submitted;
                    submitted += 1;
                    core.drv_submit(t, tag, &mut acts);
                }
            }
            SEv::Finish(id) => core.drv_finish(t, id, &mut acts),
        }
        for a in acts.drain(..) {
            match a {
                Action::Timer(tt, tm) => des.schedule(tt, SEv::Timer(tm)),
                Action::Launched { job, contention, .. } => {
                    let dur = (SLURM_DUR as f64 * contention) as Micros;
                    des.schedule(t + dur, SEv::Finish(job));
                }
                Action::Completed { .. } => {
                    completed += 1;
                    des.schedule(t, SEv::Submit);
                }
                Action::TimedOut { .. } => {}
            }
        }
        peak_resident = peak_resident.max(core.drv_resident());
        if completed >= n {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(completed, n, "{imp} slurm run incomplete");
    Row {
        core: "slurm",
        imp,
        tasks: n,
        depth,
        wall_s: wall,
        tasks_per_s: n as f64 / wall,
        peak_resident,
        des_events: des.processed(),
    }
}

// ---------------------------------------------------------------------------
// hqlite: one generic driver over both implementations
// ---------------------------------------------------------------------------

const HQ_DUR: Micros = SEC;
const HQ_ALLOC_DELAY: Micros = 5 * SEC;
const HQ_ALLOC_LIFE: Micros = 100_000 * SEC;

#[derive(Debug)]
enum HEv {
    Timer(HqTimer),
    Submit,
    AllocUp,
    TaskDone(u64),
}

// 8 workers x 16 cores = 128 concurrent tasks; queue depths above that
// keep the dispatch queue deep, which is exactly what separates the
// indexed core (frontier early-exit) from the naive full rescan.
fn hq_cfg() -> AutoAllocConfig {
    AutoAllocConfig {
        backlog: 4,
        workers_per_alloc: 1,
        max_worker_count: 8,
        alloc_request: JobRequest::new(16, 16, HQ_ALLOC_LIFE),
        dispatch_latency: 1 * MS,
    }
}

fn hq_spec(tag: u64) -> TaskSpec {
    TaskSpec { tag, cores: 1, time_request: SEC, time_limit: 100 * SEC }
}

trait HqDriver {
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<HqAction>);
    fn drv_alloc_up(&mut self, t: Micros, out: &mut Vec<HqAction>);
    fn drv_timer(&mut self, t: Micros, tm: HqTimer, out: &mut Vec<HqAction>);
    fn drv_task_done(&mut self, t: Micros, id: u64, out: &mut Vec<HqAction>);
    fn drv_resident(&self) -> usize;
}

impl HqDriver for HqCore {
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<HqAction>) {
        self.submit_task_into(t, hq_spec(tag), out);
    }
    fn drv_alloc_up(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        let _ = self.on_alloc_up_into(t, HQ_ALLOC_LIFE, 16, out);
    }
    fn drv_timer(&mut self, t: Micros, tm: HqTimer, out: &mut Vec<HqAction>) {
        self.on_timer_into(t, tm, out);
    }
    fn drv_task_done(&mut self, t: Micros, id: u64, out: &mut Vec<HqAction>) {
        self.on_task_done_into(t, id, out);
    }
    fn drv_resident(&self) -> usize {
        self.resident_tasks()
    }
}

impl HqDriver for WorkStealCore {
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<HqAction>) {
        self.submit_task_into(t, hq_spec(tag), out);
    }
    fn drv_alloc_up(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        let _ = self.on_alloc_up_into(t, HQ_ALLOC_LIFE, 16, out);
    }
    fn drv_timer(&mut self, t: Micros, tm: HqTimer, out: &mut Vec<HqAction>) {
        self.on_timer_into(t, tm, out);
    }
    fn drv_task_done(&mut self, t: Micros, id: u64, out: &mut Vec<HqAction>) {
        self.on_task_done_into(t, id, out);
    }
    fn drv_resident(&self) -> usize {
        self.resident_tasks()
    }
}

impl HqDriver for EdfCore {
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<HqAction>) {
        self.submit_task_into(t, hq_spec(tag), out);
    }
    fn drv_alloc_up(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        let _ = self.on_alloc_up_into(t, HQ_ALLOC_LIFE, 16, out);
    }
    fn drv_timer(&mut self, t: Micros, tm: HqTimer, out: &mut Vec<HqAction>) {
        self.on_timer_into(t, tm, out);
    }
    fn drv_task_done(&mut self, t: Micros, id: u64, out: &mut Vec<HqAction>) {
        self.on_task_done_into(t, id, out);
    }
    fn drv_resident(&self) -> usize {
        self.resident_tasks()
    }
}

impl HqDriver for GangCore {
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<HqAction>) {
        self.submit_task_into(t, hq_spec(tag), out);
    }
    fn drv_alloc_up(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        let _ = self.on_alloc_up_into(t, HQ_ALLOC_LIFE, 16, out);
    }
    fn drv_timer(&mut self, t: Micros, tm: HqTimer, out: &mut Vec<HqAction>) {
        self.on_timer_into(t, tm, out);
    }
    fn drv_task_done(&mut self, t: Micros, id: u64, out: &mut Vec<HqAction>) {
        self.on_task_done_into(t, id, out);
    }
    fn drv_resident(&self) -> usize {
        self.resident_tasks()
    }
}

impl HqDriver for ReferenceHqCore {
    fn drv_submit(&mut self, t: Micros, tag: u64, out: &mut Vec<HqAction>) {
        let (_, acts) = self.submit_task(t, hq_spec(tag));
        out.extend(acts);
    }
    fn drv_alloc_up(&mut self, t: Micros, out: &mut Vec<HqAction>) {
        out.extend(self.on_alloc_up(t, HQ_ALLOC_LIFE, 16));
    }
    fn drv_timer(&mut self, t: Micros, tm: HqTimer, out: &mut Vec<HqAction>) {
        out.extend(self.on_timer(t, tm));
    }
    fn drv_task_done(&mut self, t: Micros, id: u64, out: &mut Vec<HqAction>) {
        out.extend(self.on_task_done(t, id));
    }
    fn drv_resident(&self) -> usize {
        self.resident_tasks()
    }
}

fn run_hq<C: HqDriver>(
    core: &mut C,
    core_label: &'static str,
    imp: &'static str,
    n: u64,
    depth: usize,
) -> Row {
    let mut des: Des<HEv> = Des::new();
    let t0 = Instant::now();
    let window = if depth == 0 { n } else { depth.min(n as usize) as u64 };
    for _ in 0..window {
        des.schedule(0, HEv::Submit);
    }
    let mut submitted: u64 = 0;
    let mut completed: u64 = 0;
    let mut peak_resident = 0usize;
    let mut acts: Vec<HqAction> = Vec::new();
    while let Some((t, ev)) = des.pop() {
        acts.clear();
        match ev {
            HEv::Timer(tm) => core.drv_timer(t, tm, &mut acts),
            HEv::Submit => {
                if submitted < n {
                    let tag = submitted;
                    submitted += 1;
                    core.drv_submit(t, tag, &mut acts);
                }
            }
            HEv::AllocUp => core.drv_alloc_up(t, &mut acts),
            HEv::TaskDone(id) => core.drv_task_done(t, id, &mut acts),
        }
        for a in acts.drain(..) {
            match a {
                HqAction::SubmitAllocation { .. } => {
                    des.schedule(t + HQ_ALLOC_DELAY, HEv::AllocUp)
                }
                HqAction::StartTask { task, .. }
                | HqAction::StartGang { task, .. } => {
                    des.schedule(t + HQ_DUR, HEv::TaskDone(task))
                }
                HqAction::Timer(tt, tm) => des.schedule(tt, HEv::Timer(tm)),
                HqAction::TaskCompleted { .. } => {
                    completed += 1;
                    des.schedule(t, HEv::Submit);
                }
                HqAction::KillTask { .. } => {}
                // No faults in this driver: nothing ever requeues.
                HqAction::Requeued { .. } => {}
            }
        }
        peak_resident = peak_resident.max(core.drv_resident());
        if completed >= n {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(completed, n, "{imp} {core_label} run incomplete");
    Row {
        core: core_label,
        imp,
        tasks: n,
        depth,
        wall_s: wall,
        tasks_per_s: n as f64 / wall,
        peak_resident,
        des_events: des.processed(),
    }
}

// ---------------------------------------------------------------------------
// Campaign mode: the generalized workload plane at scale.  Both rows run
// the UM-Bridge + HQ stack (indexed cores) through the campaign driver —
// bursty open-loop arrivals that build a six-figure backlog, and the
// adaptive feedback policy submitting in result-dependent batches.
// ---------------------------------------------------------------------------

/// eigen-100 shapes, a 256-worker pool, no background noise: the row
/// measures campaign-driver + core throughput, not cluster weather.
fn campaign_cfg() -> CampaignConfig {
    CampaignConfig {
        app: App::Eigen100,
        seed: 42,
        cluster: ClusterSpec::hamilton8(),
        overheads: OverheadModel::quiet(),
        registration_jobs: 0,
        hq_backlog: 256,
        hq_workers: 256,
        faults: None,
    }
}

fn campaign_row(
    imp: &'static str,
    n: u64,
    res: campaign::CampaignResult,
    wall: f64,
) -> Row {
    assert_eq!(res.metrics.completed, n, "{imp} campaign incomplete");
    Row {
        core: "campaign",
        imp,
        tasks: n,
        depth: 0,
        wall_s: wall,
        tasks_per_s: n as f64 / wall,
        peak_resident: res.metrics.peak_in_flight as usize,
        des_events: res.metrics.des_events,
    }
}

fn campaign_bursty(n: u64) -> Row {
    let cfg = campaign_cfg();
    // Mean arrival rate ~1.6k tasks/s of virtual time vs ~0.4k/s of
    // service: the backlog grows to ~70% of the stream, stressing the
    // frontier early-exit dispatch at depths no fixed protocol reaches.
    let mut sub = PoissonBurst::new(App::Eigen100, n, 20 * MS, (1, 64), 42);
    let t0 = Instant::now();
    let res = campaign::run_hq(&cfg, &mut sub);
    campaign_row("bursty", n, res, t0.elapsed().as_secs_f64())
}

fn campaign_adaptive(n: u64) -> Row {
    let cfg = campaign_cfg();
    // Zero tolerance: the policy never converges early and spends the
    // whole budget in result-sized batches (barrier between rounds).
    let mut sub = AdaptiveBayes::new(App::Eigen100, n, 42)
        .with_batches(1024, 1024, 16384)
        .with_tol(0.0);
    let t0 = Instant::now();
    let res = campaign::run_hq(&cfg, &mut sub);
    campaign_row("adaptive", n, res, t0.elapsed().as_secs_f64())
}

/// The bursty campaign again, end-to-end through the work-stealing
/// stack: same arrival process, same 256-worker pool, third scheduler.
fn campaign_worksteal(n: u64) -> Row {
    let cfg = campaign_cfg();
    let mut sub = PoissonBurst::new(App::Eigen100, n, 20 * MS, (1, 64), 42);
    let t0 = Instant::now();
    let res = campaign::run_worksteal(&cfg, &mut sub);
    campaign_row("worksteal-bursty", n, res, t0.elapsed().as_secs_f64())
}

/// And through the deadline-EDF stack: same arrival process, same
/// 256-worker pool, fourth scheduler.
fn campaign_edf(n: u64) -> Row {
    let cfg = campaign_cfg();
    let mut sub = PoissonBurst::new(App::Eigen100, n, 20 * MS, (1, 64), 42);
    let t0 = Instant::now();
    let res = campaign::run_edf(&cfg, &mut sub);
    campaign_row("edf-bursty", n, res, t0.elapsed().as_secs_f64())
}

/// And through the moldable-gang stack: same arrival process, same
/// 256-worker pool, fifth scheduler (each task reserves 1..=2 workers
/// atomically, strict FCFS over the backlog).
fn campaign_gang(n: u64) -> Row {
    let cfg = campaign_cfg();
    let mut sub = PoissonBurst::new(App::Eigen100, n, 20 * MS, (1, 64), 42);
    let t0 = Instant::now();
    let res = campaign::run_gang(&cfg, &mut sub);
    campaign_row("gang-bursty", n, res, t0.elapsed().as_secs_f64())
}

/// Flaky-cluster campaign: the same bursty stream under the seeded
/// `FaultSpec::flaky` plan (node loss every ~5 virtual minutes, biased
/// transient failures, 5% stragglers at 8x) on each of the five cores.
/// Each core gets one row plus a `<core>_flaky_makespan_inflation`
/// summary entry — the virtual-time cost of riding out the same seeded
/// failure trace, relative to its own clean run.
fn campaign_flaky_rows(
    n: u64,
    rows: &mut Vec<Row>,
    summary: &mut Vec<(&'static str, Value)>,
) {
    let run = |faulty: bool, which: &str| -> (campaign::CampaignResult, f64) {
        let mut cfg = campaign_cfg();
        if faulty {
            cfg.faults = Some(FaultSpec::flaky(42));
        }
        let mut sub = PoissonBurst::new(App::Eigen100, n, 20 * MS, (1, 64), 42);
        let t0 = Instant::now();
        let res = match which {
            "slurm" => campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native),
            "hq" => campaign::run_hq(&cfg, &mut sub),
            "worksteal" => campaign::run_worksteal(&cfg, &mut sub),
            "gang" => campaign::run_gang(&cfg, &mut sub),
            _ => campaign::run_edf(&cfg, &mut sub),
        };
        (res, t0.elapsed().as_secs_f64())
    };
    for (which, imp, key) in [
        ("slurm", "flaky-slurm", "slurm_flaky_makespan_inflation"),
        ("hq", "flaky-hq", "hq_flaky_makespan_inflation"),
        ("worksteal", "flaky-worksteal",
         "worksteal_flaky_makespan_inflation"),
        ("edf", "flaky-edf", "edf_flaky_makespan_inflation"),
        ("gang", "flaky-gang", "gang_flaky_makespan_inflation"),
    ] {
        let (clean, _) = run(false, which);
        let (flaky, wall) = run(true, which);
        // Quarantined tasks still complete (as truncated records): a
        // flaky cluster may degrade throughput, never lose work.
        assert_eq!(flaky.metrics.completed, n,
                   "{which} flaky campaign lost tasks");
        let m = &flaky.metrics;
        let inflation =
            m.makespan as f64 / clean.metrics.makespan.max(1) as f64;
        println!(
            "  {which:<9} flaky: {} retries, {} quarantined, {} crashes, \
             makespan inflation {inflation:.3}x",
            m.retries, m.quarantined, m.worker_crashes
        );
        let r = Row {
            core: "campaign",
            imp,
            tasks: n,
            depth: 0,
            wall_s: wall,
            tasks_per_s: n as f64 / wall,
            peak_resident: m.peak_in_flight as usize,
            des_events: m.des_events,
        };
        r.print();
        rows.push(r);
        summary.push((key, Value::num(inflation)));
    }
}

/// DAG campaigns at scale: the dependency plane (Blocked → Ready via
/// the kernel's `DepTracker`) on every core.  The MLDA rows run
/// three-level delayed-acceptance chains — the final task count is
/// seed-dependent (chains extend under a promotion draw and surprises
/// refine), so each row records the completed count; the stage-in/out
/// rows have an exact round structure and assert it.  The summary gains
/// `mlda_level_ttn`: per core, the virtual time to the *last* result of
/// each level — the multilevel analogue of time-to-Nth-result.
fn campaign_dag_rows(
    n: u64,
    rows: &mut Vec<Row>,
    summary: &mut Vec<(&'static str, Value)>,
) {
    let run = |which: &str,
               sub: &mut dyn Submitter|
     -> (campaign::CampaignResult, f64) {
        let cfg = campaign_cfg();
        let t0 = Instant::now();
        let res = match which {
            "slurm" => campaign::run_slurm(&cfg, sub, SlurmMode::Native),
            "hq" => campaign::run_hq(&cfg, sub),
            "worksteal" => campaign::run_worksteal(&cfg, sub),
            "gang" => campaign::run_gang(&cfg, sub),
            _ => campaign::run_edf(&cfg, sub),
        };
        (res, t0.elapsed().as_secs_f64())
    };
    // Level budgets scale with the campaign knob: half the stream is
    // coarse, the fine tail is short and slow (2x runtimes).
    let levels = || {
        vec![
            MldaLevel { count: (n / 2).max(4), runtime_scale: 0.5 },
            MldaLevel { count: (n * 3 / 10).max(2), runtime_scale: 1.0 },
            MldaLevel { count: (n / 5).max(1), runtime_scale: 2.0 },
        ]
    };
    let occ = 256u64.min((n / 2).max(4));
    let mut ttn: Vec<(String, Value)> = Vec::new();
    for (which, imp) in [
        ("slurm", "mlda-slurm"),
        ("hq", "mlda-hq"),
        ("worksteal", "mlda-worksteal"),
        ("edf", "mlda-edf"),
        ("gang", "mlda-gang"),
    ] {
        let mut sub = Mlda::new(App::Eigen100, levels(), 42)
            .with_occupancy(occ, 1, occ * 4);
        let (res, wall) = run(which, &mut sub);
        let m = &res.metrics;
        assert_eq!(m.completed, m.submitted, "{imp} campaign lost tasks");
        assert!(m.dep_edges > 0, "{imp}: chains carry edges");
        assert!(m.released > 0, "{imp}: gated tasks were released");
        let r = Row {
            core: "campaign",
            imp,
            tasks: m.completed,
            depth: 0,
            wall_s: wall,
            tasks_per_s: m.completed as f64 / wall,
            peak_resident: m.peak_in_flight as usize,
            des_events: m.des_events,
        };
        r.print();
        rows.push(r);
        // Per-level time to the last result, in virtual seconds.
        let per_level: std::collections::BTreeMap<String, Value> = m
            .per_user_time_to
            .iter()
            .filter_map(|(user, ms)| {
                ms.last().map(|(_, t)| {
                    (format!("level{user}"),
                     Value::num(*t as f64 / SEC as f64))
                })
            })
            .collect();
        ttn.push((which.to_string(), Value::Obj(per_level)));
    }
    summary.push(("mlda_level_ttn", Value::Obj(ttn.into_iter().collect())));

    let fanout = 8u64;
    let rounds = (n / (fanout + 2)).max(1);
    for (which, imp) in [
        ("slurm", "stageio-slurm"),
        ("hq", "stageio-hq"),
        ("worksteal", "stageio-worksteal"),
        ("edf", "stageio-edf"),
        ("gang", "stageio-gang"),
    ] {
        let mut sub = StageInOut::new(App::Eigen100, rounds, fanout, 8, 42);
        let total = sub.total_tasks();
        let (res, wall) = run(which, &mut sub);
        let m = &res.metrics;
        assert_eq!(m.completed, total, "{imp} campaign incomplete");
        // Every compute gates on its transfer, every reduce fans in
        // over every compute: 2 * fanout edges per round.
        assert_eq!(m.dep_edges, rounds * 2 * fanout, "{imp} edge count");
        let r = Row {
            core: "campaign",
            imp,
            tasks: total,
            depth: 0,
            wall_s: wall,
            tasks_per_s: total as f64 / wall,
            peak_resident: m.peak_in_flight as usize,
            des_events: m.des_events,
        };
        r.print();
        rows.push(r);
    }
}

// ---------------------------------------------------------------------------

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn find_row<'a>(rows: &'a [Row], core: &str, imp: &str, tasks: u64) -> Option<&'a Row> {
    rows.iter()
        .find(|r| r.core == core && r.imp == imp && r.tasks == tasks)
}

fn slurm_indexed(n: u64, depth: usize) -> Row {
    let mut core = SlurmCore::new(ClusterSpec::hamilton8(),
                                  OverheadModel::quiet(), 42);
    run_slurm(&mut core, "indexed", n, depth)
}

fn slurm_naive(n: u64, depth: usize) -> Row {
    let mut core = ReferenceSlurmCore::new(ClusterSpec::hamilton8(),
                                           OverheadModel::quiet(), 42);
    run_slurm(&mut core, "naive", n, depth)
}

fn hq_indexed(n: u64, depth: usize) -> Row {
    run_hq(&mut HqCore::new(hq_cfg()), "hq", "indexed", n, depth)
}

fn hq_naive(n: u64, depth: usize) -> Row {
    run_hq(&mut ReferenceHqCore::new(hq_cfg()), "hq", "naive", n, depth)
}

/// The third scheduler through the *same* generic driver: partitioned
/// work stealing at the same workload and worker geometry as the HQ
/// rows, so the two dispatch disciplines are directly comparable.
fn worksteal_indexed(n: u64, depth: usize) -> Row {
    run_hq(&mut WorkStealCore::new(hq_cfg()), "worksteal", "indexed", n,
           depth)
}

/// The fourth scheduler through the same driver: deadline-EDF (one
/// deadline heap, laxity tie-break) at the same workload and worker
/// geometry, so the heap-ordered dispatch is directly comparable too.
fn edf_indexed(n: u64, depth: usize) -> Row {
    run_hq(&mut EdfCore::new(hq_cfg()), "edf", "indexed", n, depth)
}

/// The fifth scheduler through the same driver: strict-FCFS moldable
/// gangs (each task atomically reserves a slot on 1..=2 workers or
/// holds the queue head) at the same workload and worker geometry, so
/// the cost of the atomic multi-worker reservation is directly
/// comparable to the single-slot dispatchers.
fn gang_indexed(n: u64, depth: usize) -> Row {
    run_hq(&mut GangCore::new(hq_cfg()).with_gang(1, 2), "gang", "indexed",
           n, depth)
}

/// Depth for the allocation profile: deep enough that every core runs a
/// real steady-state pending queue, small enough that the depth-bounded
/// working set is identical between the N and 2N runs.
const ALLOC_DEPTH: usize = 1_024;

/// Steady-state allocation profile for all five cores.  Each core runs
/// the same bounded-depth drain at N and 2N tasks; the marginal
/// allocation count over the extra N tasks is the per-task cost of the
/// slab-arena hot path (slot reuse, pooled effect buffers, recycled
/// scratch).  With `UQSCHED_ALLOC_ROWS=1` the ceiling is a hard assert
/// — the CI smoke step that keeps the hot path allocation-free.
fn alloc_rows(summary: &mut Vec<(&'static str, Value)>) -> Vec<Value> {
    let n = env_u64("UQSCHED_ALLOC_TASKS", 20_000).max(1_000);
    let enforce = std::env::var("UQSCHED_ALLOC_ROWS").ok().as_deref()
        == Some("1");
    let runs: [(&'static str, &'static str, fn(u64, usize) -> Row); 5] = [
        ("slurm", "slurm_allocs_per_task", slurm_indexed),
        ("hq", "hq_allocs_per_task", hq_indexed),
        ("worksteal", "worksteal_allocs_per_task", worksteal_indexed),
        ("edf", "edf_allocs_per_task", edf_indexed),
        ("gang", "gang_allocs_per_task", gang_indexed),
    ];
    let mut out = Vec::new();
    for (core, key, run) in runs {
        // Warm-up run outside the measured windows: lazy statics, stdio
        // buffers and the first heap growths are billed to nobody.
        let _ = run(1_000, ALLOC_DEPTH);
        let a0 = ALLOC_CALLS.load(Ordering::Relaxed);
        let _ = run(n, ALLOC_DEPTH);
        let a1 = ALLOC_CALLS.load(Ordering::Relaxed);
        PEAK_BYTES.store(LIVE_BYTES.load(Ordering::Relaxed),
                         Ordering::Relaxed);
        let _ = run(2 * n, ALLOC_DEPTH);
        let a2 = ALLOC_CALLS.load(Ordering::Relaxed);
        let peak = PEAK_BYTES.load(Ordering::Relaxed);
        let marginal = (a2 - a1).saturating_sub(a1 - a0);
        let per_task = marginal as f64 / n as f64;
        println!(
            "  {core:<9} {per_task:>6.3} allocs/task (marginal over {n} \
             extra tasks, depth {ALLOC_DEPTH})  peak live {:.2} MiB",
            peak as f64 / (1024.0 * 1024.0)
        );
        if enforce {
            assert!(
                per_task <= 2.0,
                "{core}: steady-state drain costs {per_task:.3} allocs/task \
                 (ceiling 2) — a slab/pool regression on the hot path"
            );
        }
        summary.push((key, Value::num(per_task)));
        out.push(Value::obj(vec![
            ("core", Value::str(core)),
            ("tasks", Value::num(n as f64)),
            ("depth", Value::num(ALLOC_DEPTH as f64)),
            ("allocs_per_task", Value::num(per_task)),
            ("peak_live_bytes", Value::num(peak as f64)),
        ]));
    }
    out
}

fn main() {
    let max_tasks = env_u64("SCALE_TASKS", 1_000_000);
    let naive_max = env_u64("SCALE_NAIVE_TASKS", 100_000);

    println!("=== scale benchmark (indexed vs naive scheduler cores) ===");
    let mut rows: Vec<Row> = Vec::new();

    // Head-to-head at matched configurations.  The naive cores go
    // quadratic with queue depth, so their depths are capped to keep the
    // baseline runnable; the indexed cores run the same configs for a
    // like-for-like speedup, then scale out to max_tasks.
    let h2h: &[(u64, usize, usize)] = &[
        // (tasks, slurm depth, hq depth)
        (10_000, 65_536, 2_048),
        (100_000, 65_536, 2_048),
    ];
    println!("-- head-to-head (same workload, both implementations) --");
    for &(n, sd, hd) in h2h {
        if n > naive_max {
            continue;
        }
        for r in [
            slurm_naive(n, sd),
            slurm_indexed(n, sd),
            hq_naive(n, hd),
            hq_indexed(n, hd),
        ] {
            r.print();
            rows.push(r);
        }
    }

    // Scale-out: indexed cores only, up to the million-task target, at
    // several queue depths (0 = everything submitted up front).  The
    // worksteal and edf rows run the third and fourth schedulers
    // through the same driver and workload as the hq rows.
    println!("-- scale-out (indexed cores, all five schedulers) --");
    let mut sizes: Vec<u64> = [250_000u64, 500_000, 1_000_000]
        .into_iter()
        .filter(|&s| s <= max_tasks)
        .collect();
    if sizes.is_empty() {
        // Smoke runs with a small SCALE_TASKS still cover every core.
        sizes.push(max_tasks);
    }
    for &n in &sizes {
        for depth in [8_192usize, 0] {
            for r in [
                slurm_indexed(n, depth),
                hq_indexed(n, depth),
                worksteal_indexed(n, depth),
                edf_indexed(n, depth),
                gang_indexed(n, depth),
            ] {
                r.print();
                rows.push(r);
            }
        }
    }

    // Campaign mode: generalized workloads through the campaign plane.
    let campaign_tasks = env_u64("SCALE_CAMPAIGN_TASKS", 100_000);
    if campaign_tasks > 0 {
        println!("-- campaign mode (bursty + adaptive on hq, bursty on \
                  worksteal + edf + gang) --");
        for r in [
            campaign_bursty(campaign_tasks),
            campaign_adaptive(campaign_tasks),
            campaign_worksteal(campaign_tasks),
            campaign_edf(campaign_tasks),
            campaign_gang(campaign_tasks),
        ] {
            r.print();
            rows.push(r);
        }
    }

    // Opt-in CI floor: machines differ, so the absolute throughput
    // assert only fires when the harness pins a floor for its runner.
    let floor = env_u64("UQSCHED_MIN_TASKS_PER_S", 0) as f64;
    if floor > 0.0 {
        for r in rows.iter().filter(|r| r.imp == "indexed") {
            assert!(
                r.tasks_per_s >= floor,
                "{} at {} tasks: {:.0} tasks/s under floor {floor}",
                r.core, r.tasks, r.tasks_per_s
            );
        }
    }

    // Headline derived numbers.
    let mut summary: Vec<(&'static str, Value)> = Vec::new();

    // Steady-state allocation profile: the slab-arena budget, one row
    // per core (see `alloc_rows`).
    println!("-- allocation profile (counting allocator, all five \
              cores) --");
    let allocs = alloc_rows(&mut summary);

    // Flaky-cluster mode: the bursty campaign under the seeded fault
    // plan, one row per core, inflation vs each core's clean run.
    if campaign_tasks > 0 {
        println!("-- flaky-cluster campaign (all five cores, seeded \
                  fault plan) --");
        campaign_flaky_rows(campaign_tasks, &mut rows, &mut summary);
    }

    // DAG campaigns: MLDA chains + stage-in/out rounds on every core.
    if campaign_tasks > 0 {
        println!("-- dag campaigns (mlda + stageio, all five cores) --");
        campaign_dag_rows(campaign_tasks, &mut rows, &mut summary);
    }
    for core in ["slurm", "hq"] {
        if let (Some(naive), Some(indexed)) = (
            find_row(&rows, core, "naive", 100_000),
            find_row(&rows, core, "indexed", 100_000),
        ) {
            let speedup = naive.wall_s / indexed.wall_s;
            println!("{core}: 100k-task speedup indexed/naive = {speedup:.1}x");
            summary.push(match core {
                "slurm" => ("slurm_speedup_100k", Value::num(speedup)),
                _ => ("hq_speedup_100k", Value::num(speedup)),
            });
        }
        // Sub-quadratic check: doubling tasks must less than quadruple
        // wall time (500k -> 1M at the same depth).
        let a = rows.iter().find(|r| {
            r.core == core && r.imp == "indexed" && r.tasks == 500_000
                && r.depth == 8_192
        });
        let b = rows.iter().find(|r| {
            r.core == core && r.imp == "indexed" && r.tasks == 1_000_000
                && r.depth == 8_192
        });
        if let (Some(a), Some(b)) = (a, b) {
            let ratio = b.wall_s / a.wall_s.max(1e-9);
            println!(
                "{core}: 500k -> 1M wall-time ratio = {ratio:.2} (sub-quadratic iff < 4)"
            );
            summary.push(match core {
                "slurm" => ("slurm_1m_over_500k", Value::num(ratio)),
                _ => ("hq_1m_over_500k", Value::num(ratio)),
            });
        }
    }

    // Third-scheduler comparison: same workload, worker pool and driver
    // as the hq rows, different dispatch discipline.
    let hq_row = rows.iter().find(|r| {
        r.core == "hq" && r.imp == "indexed" && r.depth == 8_192
    });
    let ws_row = rows.iter().find(|r| {
        r.core == "worksteal" && r.imp == "indexed" && r.depth == 8_192
    });
    if let (Some(hq), Some(ws)) = (hq_row, ws_row) {
        let ratio = ws.tasks_per_s / hq.tasks_per_s.max(1e-9);
        println!(
            "worksteal vs hq throughput at depth 8192 ({} tasks): {ratio:.2}x",
            ws.tasks
        );
        summary.push(("worksteal_over_hq_depth8192", Value::num(ratio)));
    }
    let edf_row = rows.iter().find(|r| {
        r.core == "edf" && r.imp == "indexed" && r.depth == 8_192
    });
    if let (Some(hq), Some(edf)) = (hq_row, edf_row) {
        let ratio = edf.tasks_per_s / hq.tasks_per_s.max(1e-9);
        println!(
            "edf vs hq throughput at depth 8192 ({} tasks): {ratio:.2}x",
            edf.tasks
        );
        summary.push(("edf_over_hq_depth8192", Value::num(ratio)));
    }
    let gang_row = rows.iter().find(|r| {
        r.core == "gang" && r.imp == "indexed" && r.depth == 8_192
    });
    if let (Some(hq), Some(gang)) = (hq_row, gang_row) {
        let ratio = gang.tasks_per_s / hq.tasks_per_s.max(1e-9);
        println!(
            "gang vs hq throughput at depth 8192 ({} tasks): {ratio:.2}x",
            gang.tasks
        );
        summary.push(("gang_over_hq_depth8192", Value::num(ratio)));
    }

    let out_path = std::env::var("SCALE_OUT")
        .unwrap_or_else(|_| "BENCH_scale.json".to_string());
    let doc = Value::obj(vec![
        ("bench", Value::str("scale")),
        ("max_tasks", Value::num(max_tasks as f64)),
        ("naive_max_tasks", Value::num(naive_max as f64)),
        ("campaign_tasks", Value::num(campaign_tasks as f64)),
        ("results", Value::arr(rows.iter().map(Row::json).collect())),
        ("allocs", Value::arr(allocs)),
        ("summary", Value::Obj(
            summary.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )),
    ]);
    std::fs::write(&out_path, uqsched::json::write(&doc))
        .expect("write BENCH_scale.json");
    println!("wrote {out_path}");
}
