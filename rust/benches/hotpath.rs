//! L3/L1 hot-path microbenchmarks (the in-crate criterion substitute):
//!
//!   * PJRT GP prediction throughput (single and batched entry)
//!   * gs2 chunk latency (the serving inner loop)
//!   * JSON parse/serialise of evaluate bodies
//!   * HTTP+UM-Bridge round-trip latency and throughput
//!   * end-to-end balancer throughput (queue -> registry -> forward)
//!   * multi-model balancer throughput: N models through one front
//!     door, fixed forwarder pool, zero per-evaluation thread spawns —
//!     run once per live scheduler core (fcfs | worksteal | edf |
//!     gang), so the serving plane's scheduler ablation is measured
//!     under real HTTP load
//!   * shard scaling: the dispatch plane driven directly (no HTTP) at
//!     1/2/4/8 shards per model for every live policy; the headline
//!     submit/s uses the partitioned critical path (max per-shard busy
//!     time), which measures the plane's parallelism independently of
//!     how many host cores the bench machine has
//!
//! The PJRT sections need `make artifacts` and self-skip without them;
//! the multi-model sections run anywhere (synthetic models over the
//! in-process LocalBackend) and write `BENCH_hotpath.json` with one row
//! per scheduler (each carrying the balancer's /Stats document:
//! queue-wait + forward histograms) plus the `shard_scaling` rows.
//!
//! Knobs: `UQSCHED_HOTPATH_ITERS` (default 300 evals per client),
//! `UQSCHED_HOTPATH_MODELS` (default 4), `UQSCHED_SHARD_EVALS`
//! (default 1000 evals per model per shard-scaling cell).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use uqsched::coordinator::{start_live, BalancerConfig, LoadBalancer,
                           LocalBackend};
use uqsched::json::{self, Value};
use uqsched::models::{self, GP_NAME};
use uqsched::runtime::Engine;
use uqsched::sched::LivePolicy;
use uqsched::umbridge::{serve_models, HttpModel, Model};
use uqsched::workload::lhs;

fn bench<F: FnMut() -> ()>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.min(3) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed().as_secs_f64();
    let per = dt / iters as f64;
    println!("  {name:<42} {:>10.1} ops/s   {:>10.3} ms/op",
             1.0 / per, per * 1e3);
    per
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    println!("=== hotpath microbenchmarks ===");
    let dir = std::env::var("UQSCHED_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    match Engine::new(Path::new(&dir)) {
        Ok(eng) => pjrt_sections(Arc::new(eng)),
        Err(e) => println!("  SKIP PJRT sections (no artifacts: {e:#})"),
    }
    // The serving-plane scheduler ablation: the same workload through
    // every live core, one BENCH_hotpath.json row per scheduler.
    let rows: Vec<Value> = [LivePolicy::Fcfs, LivePolicy::WorkSteal,
                            LivePolicy::Edf, LivePolicy::Gang]
        .into_iter()
        .map(multi_model_section)
        .collect();
    let degraded = degraded_fleet_section();
    let shard_rows = shard_scaling_section();
    let doc = Value::obj(vec![
        ("schedulers", Value::arr(rows)),
        ("degraded_fleet", degraded),
        ("shard_scaling", shard_rows),
    ]);
    std::fs::write("BENCH_hotpath.json", json::write(&doc))
        .expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json (one row per balancer scheduler, \
              per-model queue-wait/forward histograms, the degraded-fleet \
              section and the shard_scaling rows)");
    println!("hotpath done");
    std::process::exit(0); // skip slow teardown of live threads
}

fn pjrt_sections(eng: Arc<Engine>) {
    eng.warmup(&["gp_predict_b16", "gp_predict_b256", "gs2_chunk"])
        .expect("warmup");

    let points = lhs(256, 7);

    // L1/L2: PJRT GP prediction.
    let gp = models::GpModel::new(eng.clone());
    let one: Vec<Vec<f64>> = vec![points[0].to_vec()];
    bench("gp predict (b16 entry, 1 point)", 200, || {
        gp.predict_batch(&one).unwrap();
    });
    let batch16: Vec<Vec<f64>> = points[..16].iter().map(|p| p.to_vec())
        .collect();
    let per16 = bench("gp predict (b16 entry, 16 points)", 200, || {
        gp.predict_batch(&batch16).unwrap();
    });
    println!("    -> {:.0} predictions/s through the b16 entry",
             16.0 / per16);
    let flat256: Vec<f32> = points.iter().flat_map(|p| p.iter())
        .map(|&v| v as f32).collect();
    let per256 = bench("gp predict (b256 entry, 256 points)", 100, || {
        eng.execute("gp_predict_b256", &[flat256.clone()]).unwrap();
    });
    println!("    -> {:.0} predictions/s through the b256 entry",
             256.0 / per256);

    // gs2 chunk latency.
    let gs2 = models::Gs2Model::new(eng.clone());
    let st = gs2.initial_state();
    let th: Vec<f32> = points[1].iter().map(|&v| v as f32).collect();
    bench("gs2 chunk (64 power iterations)", 100, || {
        eng.execute("gs2_chunk", &[th.clone(), st.clone()]).unwrap();
    });

    // JSON substrate on an /Evaluate body.
    let body = json::write(&Value::obj(vec![
        ("name", Value::str("gp")),
        ("input", Value::from_f64s2(&[points[0].to_vec()])),
        ("config", Value::Obj(Default::default())),
    ]));
    bench("json parse /Evaluate body", 20_000, || {
        json::parse(&body).unwrap();
    });

    // HTTP + UM-Bridge round trip (direct to a model server).
    let srv = serve_models(
        vec![models::by_name(eng.clone(), GP_NAME).unwrap()], 0).unwrap();
    let mut client = HttpModel::connect(&srv.url(), GP_NAME).unwrap();
    let cfgv = Value::Obj(Default::default());
    bench("umbridge evaluate round-trip (direct)", 300, || {
        client.evaluate(&[points[2].to_vec()], &cfgv).unwrap();
    });

    // End-to-end through the balancer (persistent servers, hq backend).
    let stack = start_live(eng.clone(), &[GP_NAME], "hq", 2, 2000.0, true,
                           LivePolicy::Fcfs)
        .expect("live stack");
    // Wait for a server to register (warm start spawns it).
    let t0 = Instant::now();
    while stack.balancer.registry().total() == 0 {
        if t0.elapsed().as_secs() > 30 {
            panic!("no server registered");
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let mut lb_client = HttpModel::connect(&stack.balancer.url(), GP_NAME)
        .unwrap();
    bench("balancer end-to-end evaluate (hq backend)", 300, || {
        lb_client.evaluate(&[points[4].to_vec()], &cfgv).unwrap();
    });
}

/// N models through one balancer front door: per-model scheduler
/// cores, the fixed forwarder pool and registry leases on the hot path
/// — no per-evaluation thread spawn anywhere.  Artifact-free
/// (synthetic models, LocalBackend).  Returns the scheduler's
/// BENCH_hotpath.json row.
fn multi_model_section(scheduler: LivePolicy) -> Value {
    let n_models = env_usize("UQSCHED_HOTPATH_MODELS", 4).max(1);
    let iters = env_usize("UQSCHED_HOTPATH_ITERS", 300).max(1);
    let clients_per_model = 2usize;

    let names: Vec<String> =
        (0..n_models).map(|i| format!("syn-{i}")).collect();
    let backend = LocalBackend::new(Arc::new(|name: &str| {
        Ok(Arc::new(models::SyntheticModel::new(name, &[4], &[2]))
            as Arc<dyn Model>)
    }));
    let cfg = BalancerConfig {
        models: names.clone(),
        max_servers: 2,
        forwarders: 8,
        scheduler,
        ..Default::default()
    };
    let mut lb = LoadBalancer::start(cfg, backend).expect("balancer");
    let url = lb.url();
    let t0 = Instant::now();
    while lb.registry().total() < n_models {
        if t0.elapsed().as_secs() > 30 {
            panic!("servers failed to register");
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    let t0 = Instant::now();
    let threads: Vec<_> = names
        .iter()
        .flat_map(|name| {
            (0..clients_per_model).map(|c| {
                let url = url.clone();
                let name = name.clone();
                std::thread::spawn(move || {
                    let mut m = HttpModel::connect(&url, &name).unwrap();
                    let cfgv = Value::Obj(Default::default());
                    for i in 0..iters {
                        let x = vec![c as f64, i as f64, 1.0, 2.0];
                        let sum: f64 = x.iter().sum();
                        let out = m.evaluate(&[x], &cfgv).unwrap();
                        assert_eq!(out[0][0], sum);
                    }
                })
            }).collect::<Vec<_>>()
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let total = (n_models * clients_per_model * iters) as f64;
    println!(
        "  multi-model balancer [{}] ({n_models} models, {} clients)    \
         {:>10.1} evals/s   {:>10.3} ms/eval",
        scheduler.label(),
        n_models * clients_per_model,
        total / dt,
        dt / total * 1e3
    );

    let stats = lb.stats_json();
    // Thundering-herd check: one targeted notify_one per dispatched
    // order, so wakeups/request stays ~1 (broadcast wakeups would put
    // it at the forwarder-pool size).
    let wakeups = lb.plane().wakeups_total();
    let row = Value::obj(vec![
        ("scheduler", Value::str(scheduler.label())),
        ("multi_model", Value::obj(vec![
            ("models", Value::num(n_models as f64)),
            ("clients", Value::num((n_models * clients_per_model) as f64)),
            ("evals", Value::num(total)),
            ("wall_s", Value::num(dt)),
            ("evals_per_s", Value::num(total / dt)),
            ("wakeups_per_request", Value::num(wakeups as f64 / total)),
        ])),
        ("stats", stats),
    ]);
    lb.shutdown();
    row
}

/// The tentpole headline: the sharded dispatch plane driven directly
/// (plane submit -> shard thread -> order queue -> inline executor, no
/// HTTP, no front door) at 1/2/4/8 shards per model, once per live
/// policy.  Each cell reports wall time plus the **partitioned critical
/// path** (max per-shard busy microseconds): submit/s and served/s
/// against the critical path measure how the plane's work parallelizes
/// across shards independently of the bench host's core count.
fn shard_scaling_section() -> Value {
    let mut rows = Vec::new();
    for policy in [LivePolicy::Fcfs, LivePolicy::WorkSteal,
                   LivePolicy::Edf, LivePolicy::Gang] {
        for shards in [1usize, 2, 4, 8] {
            rows.push(shard_scaling_cell(policy, shards));
        }
    }
    Value::arr(rows)
}

fn shard_scaling_cell(policy: LivePolicy, shards: usize) -> Value {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::time::Duration;
    use uqsched::coordinator::{BalancerStats, DispatchPlane, PlaneConfig,
                               Registry, SubmitOutcome};
    use uqsched::sched::realtime::RetryPolicy;
    use uqsched::umbridge::ModelContract;

    let evals = env_usize("UQSCHED_SHARD_EVALS", 1000).max(1);
    let n_models = 2usize;
    let workers_per_model = 8usize;

    let names: Vec<String> =
        (0..n_models).map(|i| format!("shard-syn-{i}")).collect();
    let registry = Arc::new(Registry::new());
    let stats = Arc::new(BalancerStats::new(&names));
    let plane = DispatchPlane::start(
        PlaneConfig {
            models: names.clone(),
            shards_per_model: shards,
            queue_capacity: evals * 4,
            scheduler: policy,
            retry: RetryPolicy::default(),
            request_timeout: Duration::from_secs(60),
            persistent_servers: true,
        },
        registry.clone(),
        stats,
        Arc::new(AtomicU64::new(0)),
    );
    let contract = ModelContract {
        input_sizes: vec![1],
        output_sizes: vec![1],
    };
    for (j, m) in names.iter().enumerate() {
        for k in 0..workers_per_model {
            let ep = format!("shard-bench-{j}-{k}");
            registry.register(&ep, m, &contract);
            plane.worker_up(&ep, m);
        }
    }
    let t0 = Instant::now();
    while names.iter().any(|m| plane.workers_for(m) < workers_per_model) {
        if t0.elapsed().as_secs() > 30 {
            panic!("shard bench workers failed to announce");
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    // Executors: one per shard index, completing orders inline — the
    // forward hop itself is not under test, only the dispatch plane.
    let stop = Arc::new(AtomicBool::new(false));
    let execs: Vec<_> = (0..plane.shard_count())
        .map(|s| {
            let plane = plane.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    if let Some(order) =
                        plane.take_order(s, Duration::from_millis(10))
                    {
                        plane.complete_order(order, Ok("done".into()));
                    }
                }
            })
        })
        .collect();

    let t0 = Instant::now();
    // One submitting client per model; each waits for all of its
    // evaluations to resolve.
    let subs: Vec<_> = names
        .iter()
        .map(|m| {
            let plane = plane.clone();
            let m = m.clone();
            std::thread::spawn(move || {
                let mut handles = Vec::with_capacity(evals);
                for i in 0..evals {
                    loop {
                        match plane.submit(&m, format!("p-{i}")) {
                            SubmitOutcome::Queued(h) => {
                                handles.push(h);
                                break;
                            }
                            SubmitOutcome::Full => std::thread::sleep(
                                Duration::from_micros(200),
                            ),
                            _ => panic!("shard bench submit rejected"),
                        }
                    }
                }
                for h in handles {
                    let r = h
                        .wait_deadline(
                            Instant::now() + Duration::from_secs(60),
                        )
                        .expect("shard bench eval resolved");
                    assert!(r.is_ok(), "shard bench eval failed");
                }
            })
        })
        .collect();
    for t in subs {
        t.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    for t in execs {
        t.join().unwrap();
    }

    let counts = plane.counts();
    let submitted: u64 = counts.iter().map(|(_, c)| c.submitted).sum();
    let served: u64 = counts.iter().map(|(_, c)| c.served).sum();
    let busy_max_us =
        counts.iter().map(|(_, c)| c.busy_us).max().unwrap_or(1).max(1);
    let busy_total_us: u64 = counts.iter().map(|(_, c)| c.busy_us).sum();
    let wakeups = plane.wakeups_total();
    plane.shutdown();

    let busy_max_s = busy_max_us as f64 / 1e6;
    let submit_per_s = submitted as f64 / busy_max_s;
    let served_per_s = served as f64 / busy_max_s;
    let wpr = wakeups as f64 / submitted.max(1) as f64;
    println!(
        "  shard scaling [{:<9} x{shards}]  {submit_per_s:>12.0} submit/s  \
         {served_per_s:>12.0} served/s (critical path)  wall {wall:.3}s  \
         wakeups/req {wpr:.2}",
        policy.label(),
    );
    Value::obj(vec![
        ("scheduler", Value::str(policy.label())),
        ("shards", Value::num(shards as f64)),
        ("models", Value::num(n_models as f64)),
        ("workers_per_model", Value::num(workers_per_model as f64)),
        ("evals", Value::num(submitted as f64)),
        ("served", Value::num(served as f64)),
        ("wall_s", Value::num(wall)),
        ("busy_max_s", Value::num(busy_max_s)),
        ("busy_total_s", Value::num(busy_total_us as f64 / 1e6)),
        ("submit_per_s", Value::num(submit_per_s)),
        ("served_per_s", Value::num(served_per_s)),
        ("wakeups_per_request", Value::num(wpr)),
    ])
}

/// Degraded-fleet section: the same balancer workload while an injector
/// kills a server mid-evaluation every ~40th call — every death drops
/// the forwarder's socket (a genuine transport failure), so the
/// lease-failure retry path, worker-lost accounting and server respawn
/// all run under real HTTP load.  Returns the `degraded_fleet` row of
/// BENCH_hotpath.json (throughput under churn plus the balancer's
/// /Stats document with the retry counters and backoff histogram).
fn degraded_fleet_section() -> Value {
    use std::sync::atomic::{AtomicU64, Ordering};

    let iters = env_usize("UQSCHED_HOTPATH_ITERS", 300).max(1);
    let n_models = 2usize;
    let clients_per_model = 2usize;
    const KILL_EVERY: u64 = 40;

    // The injected deaths are expected: keep their panic traces out of
    // the bench output, delegate everything else to the default hook.
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let injected = info
            .payload()
            .downcast_ref::<&str>()
            .is_some_and(|s| s.contains("injected server death"));
        if !injected {
            prev(info);
        }
    }));

    struct FlakyModel {
        inner: models::SyntheticModel,
        calls: Arc<AtomicU64>,
    }
    impl Model for FlakyModel {
        fn name(&self) -> &str {
            self.inner.name()
        }
        fn input_sizes(&self) -> Vec<usize> {
            self.inner.input_sizes()
        }
        fn output_sizes(&self) -> Vec<usize> {
            self.inner.output_sizes()
        }
        fn evaluate(&self, inputs: &[Vec<f64>], config: &Value)
                    -> anyhow::Result<Vec<Vec<f64>>> {
            if self.calls.fetch_add(1, Ordering::Relaxed) % KILL_EVERY == 0 {
                panic!("injected server death (bench)");
            }
            self.inner.evaluate(inputs, config)
        }
    }

    let calls = Arc::new(AtomicU64::new(1)); // call 0 would die instantly
    let calls2 = calls.clone();
    let names: Vec<String> =
        (0..n_models).map(|i| format!("syn-{i}")).collect();
    let backend = LocalBackend::new(Arc::new(move |name: &str| {
        Ok(Arc::new(FlakyModel {
            inner: models::SyntheticModel::new(name, &[4], &[2]),
            calls: calls2.clone(),
        }) as Arc<dyn Model>)
    }));
    let cfg = BalancerConfig {
        models: names.clone(),
        max_servers: 2,
        forwarders: 8,
        ..Default::default()
    };
    let mut lb = LoadBalancer::start(cfg, backend).expect("balancer");
    let url = lb.url();
    let t0 = Instant::now();
    while lb.registry().total() < n_models {
        if t0.elapsed().as_secs() > 30 {
            panic!("servers failed to register");
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }

    let ok = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let threads: Vec<_> = names
        .iter()
        .flat_map(|name| {
            (0..clients_per_model).map(|c| {
                let url = url.clone();
                let name = name.clone();
                let ok = ok.clone();
                let failed = failed.clone();
                std::thread::spawn(move || {
                    let mut m = HttpModel::connect(&url, &name).unwrap();
                    let cfgv = Value::Obj(Default::default());
                    for i in 0..iters {
                        let x = vec![c as f64, i as f64, 1.0, 2.0];
                        let sum: f64 = x.iter().sum();
                        match m.evaluate(&[x], &cfgv) {
                            Ok(out) => {
                                assert_eq!(out[0][0], sum);
                                ok.fetch_add(1, Ordering::Relaxed);
                            }
                            // Budget-exhausted evaluations surface as
                            // errors (counted, not fatal): a kill can
                            // land on the retry attempt too.
                            Err(_) => {
                                failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                })
            }).collect::<Vec<_>>()
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let total = (n_models * clients_per_model * iters) as u64;
    let completed = ok.load(Ordering::Relaxed);
    let errors = failed.load(Ordering::Relaxed);
    assert_eq!(completed + errors, total, "degraded fleet lost requests");
    println!(
        "  degraded fleet ({n_models} models, kill every {KILL_EVERY})      \
         {:>10.1} evals/s   {completed} ok, {errors} exhausted budget",
        completed as f64 / dt
    );

    let stats = lb.stats_json();
    let row = Value::obj(vec![
        ("models", Value::num(n_models as f64)),
        ("clients", Value::num((n_models * clients_per_model) as f64)),
        ("kill_every", Value::num(KILL_EVERY as f64)),
        ("evals", Value::num(total as f64)),
        ("completed", Value::num(completed as f64)),
        ("errors", Value::num(errors as f64)),
        ("wall_s", Value::num(dt)),
        ("evals_per_s", Value::num(completed as f64 / dt)),
        ("stats", stats),
    ]);
    lb.shutdown();
    row
}
