//! L3/L1 hot-path microbenchmarks (the in-crate criterion substitute):
//!
//!   * PJRT GP prediction throughput (single and batched entry)
//!   * gs2 chunk latency (the serving inner loop)
//!   * JSON parse/serialise of evaluate bodies
//!   * HTTP+UM-Bridge round-trip latency and throughput
//!   * end-to-end balancer throughput (queue -> registry -> forward)
//!
//! Used by the performance pass (EXPERIMENTS.md section Perf); each
//! measurement prints ops/s and per-op latency.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use uqsched::coordinator::start_live;
use uqsched::json::{self, Value};
use uqsched::models::{self, GP_NAME};
use uqsched::runtime::Engine;
use uqsched::umbridge::{serve_models, HttpModel};
use uqsched::workload::{lhs, scenario, App};

fn bench<F: FnMut() -> ()>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.min(3) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed().as_secs_f64();
    let per = dt / iters as f64;
    println!("  {name:<42} {:>10.1} ops/s   {:>10.3} ms/op",
             1.0 / per, per * 1e3);
    per
}

fn main() {
    println!("=== hotpath microbenchmarks ===");
    let dir = std::env::var("UQSCHED_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let eng = Arc::new(Engine::new(Path::new(&dir)).expect("engine"));
    eng.warmup(&["gp_predict_b16", "gp_predict_b256", "gs2_chunk"])
        .expect("warmup");

    let points = lhs(256, 7);

    // L1/L2: PJRT GP prediction.
    let gp = models::GpModel::new(eng.clone());
    let one: Vec<Vec<f64>> = vec![points[0].to_vec()];
    bench("gp predict (b16 entry, 1 point)", 200, || {
        gp.predict_batch(&one).unwrap();
    });
    let batch16: Vec<Vec<f64>> = points[..16].iter().map(|p| p.to_vec())
        .collect();
    let per16 = bench("gp predict (b16 entry, 16 points)", 200, || {
        gp.predict_batch(&batch16).unwrap();
    });
    println!("    -> {:.0} predictions/s through the b16 entry",
             16.0 / per16);
    let flat256: Vec<f32> = points.iter().flat_map(|p| p.iter())
        .map(|&v| v as f32).collect();
    let per256 = bench("gp predict (b256 entry, 256 points)", 100, || {
        eng.execute("gp_predict_b256", &[flat256.clone()]).unwrap();
    });
    println!("    -> {:.0} predictions/s through the b256 entry",
             256.0 / per256);

    // gs2 chunk latency.
    let gs2 = models::Gs2Model::new(eng.clone());
    let st = gs2.initial_state();
    let th: Vec<f32> = points[1].iter().map(|&v| v as f32).collect();
    bench("gs2 chunk (64 power iterations)", 100, || {
        eng.execute("gs2_chunk", &[th.clone(), st.clone()]).unwrap();
    });

    // JSON substrate on an /Evaluate body.
    let body = json::write(&Value::obj(vec![
        ("name", Value::str("gp")),
        ("input", Value::from_f64s2(&[points[0].to_vec()])),
        ("config", Value::Obj(Default::default())),
    ]));
    bench("json parse /Evaluate body", 20_000, || {
        json::parse(&body).unwrap();
    });

    // HTTP + UM-Bridge round trip (direct to a model server).
    let srv = serve_models(
        vec![models::by_name(eng.clone(), GP_NAME).unwrap()], 0).unwrap();
    let mut client = HttpModel::connect(&srv.url(), GP_NAME).unwrap();
    let cfgv = Value::Obj(Default::default());
    bench("umbridge evaluate round-trip (direct)", 300, || {
        client.evaluate(&[points[2].to_vec()], &cfgv).unwrap();
    });

    // End-to-end through the balancer (persistent servers, hq backend).
    let stack = start_live(eng.clone(), GP_NAME, "hq", 2,
                           &scenario(App::Gp), 2000.0, true)
        .expect("live stack");
    // Wait for a server to register.
    let t0 = Instant::now();
    while stack.balancer.registry().total() == 0 {
        if t0.elapsed().as_secs() > 30 {
            panic!("no server registered");
        }
        // Post one request to trigger scale-up.
        if let Ok(mut c) = HttpModel::connect(&stack.balancer.url(), GP_NAME) {
            let _ = c.evaluate(&[points[3].to_vec()], &cfgv);
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let mut lb_client = HttpModel::connect(&stack.balancer.url(), GP_NAME)
        .unwrap();
    bench("balancer end-to-end evaluate (hq backend)", 300, || {
        lb_client.evaluate(&[points[4].to_vec()], &cfgv).unwrap();
    });

    println!("hotpath done");
    std::process::exit(0); // skip slow teardown of live threads
}
