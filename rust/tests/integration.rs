//! Integration tests across the whole stack.
//!
//! `runtime_*` tests need `make artifacts` to have run (they are skipped
//! with a clear message otherwise).  The balancer tests run the real live
//! stack: slurmlite daemon + backend + balancer + model-server threads +
//! PJRT evaluation over HTTP.

use std::path::PathBuf;
use std::sync::Arc;

use uqsched::coordinator::start_live;
use uqsched::sched::LivePolicy;
use uqsched::json::Value;
use uqsched::models;
use uqsched::runtime::{check_testvec, Engine};
use uqsched::umbridge::HttpModel;
use uqsched::workload::lhs;

fn artifacts_dir() -> Option<PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

fn engine() -> Option<Arc<Engine>> {
    let dir = artifacts_dir()?;
    Some(Arc::new(Engine::new(&dir).expect("engine")))
}

macro_rules! need_artifacts {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("SKIP: artifacts missing; run `make artifacts`");
                return;
            }
        }
    };
}

// ---- runtime vs golden vectors (the AOT boundary) -------------------------

#[test]
fn runtime_matches_python_golden_vectors() {
    let eng = need_artifacts!();
    for name in eng.entry_names() {
        let err = check_testvec(&eng, &name).expect(&name);
        assert!(err < 1e-4, "{name}: max rel err {err}");
    }
}

#[test]
fn runtime_eigen_matches_rust_generator() {
    // The seeded benchmark matrix crosses the language boundary
    // bit-identically; eigenvalues must therefore be reproducible.
    let eng = need_artifacts!();
    let model = models::EigenModel::small(eng);
    let (w1, off1) = model.solve_seed(42).unwrap();
    let (w2, _) = model.solve_seed(42).unwrap();
    assert_eq!(w1, w2);
    assert!(off1 < 1e-2, "not converged: {off1}");
    // Eigenvalues ascending.
    assert!(w1.windows(2).all(|p| p[0] <= p[1] + 1e-9));
    // Trace check vs the generator.
    let n = 100;
    let a = uqsched::util::Rng::symmetric_matrix(42, n);
    let trace: f64 = (0..n).map(|i| a[i * n + i] as f64).sum();
    let sum: f64 = w1.iter().sum();
    assert!((trace - sum).abs() < 1e-2, "{trace} vs {sum}");
}

#[test]
fn runtime_gs2_converges_and_varies() {
    let eng = need_artifacts!();
    let gs2 = models::Gs2Model::new(eng);
    let pts = lhs(6, 99);
    let mut chunk_counts = Vec::new();
    for p in &pts {
        let (_g, _w, res, chunks) = gs2.solve(&p.to_vec(), Some(150)).unwrap();
        assert!(res.is_finite());
        chunk_counts.push(chunks);
    }
    // Input-dependent runtimes: the counts must not all be equal.
    let min = chunk_counts.iter().min().unwrap();
    let max = chunk_counts.iter().max().unwrap();
    assert!(max > min, "no runtime variation: {chunk_counts:?}");
}

#[test]
fn runtime_gp_agrees_with_gs2_direction() {
    // The surrogate was trained on gs2lite: at a strongly-driven point
    // the predicted growth rate must exceed a strongly-damped point's.
    let eng = need_artifacts!();
    let gp = models::GpModel::new(eng);
    let hot = vec![3.0, 0.5, 9.0, 5.5, 0.25, 0.0, 0.4];
    let cold = vec![8.0, 4.5, 0.5, 0.6, 0.0, 0.1, 0.9];
    let (means, _) = gp.predict_batch(&[hot, cold]).unwrap();
    assert!(means[0][0] > means[1][0],
            "gp ordering wrong: {means:?}");
}

// ---- live stack ------------------------------------------------------------

#[test]
fn balancer_hq_end_to_end() {
    let eng = need_artifacts!();
    let stack = start_live(eng, &[models::GP_NAME], "hq", 2, 5000.0, true,
                           LivePolicy::Fcfs)
        .expect("live stack");
    let mut client = HttpModel::connect(&stack.balancer.url(),
                                        models::GP_NAME)
        .expect("client");
    let cfg = Value::Obj(Default::default());
    let pts = lhs(6, 3);
    for p in &pts {
        let out = client.evaluate(&[p.to_vec()], &cfg).expect("evaluate");
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[1].len(), 2);
        assert!(out[1][0] >= 0.0, "variance must be nonnegative");
    }
    // The preliminary registration queries happened (>=5 per server).
    assert!(stack.balancer.registration_queries
                .load(std::sync::atomic::Ordering::Relaxed) >= 5);
    assert!(stack.balancer.requests_served
                .load(std::sync::atomic::Ordering::Relaxed) >= 6);
}

#[test]
fn balancer_slurm_backend_end_to_end() {
    let eng = need_artifacts!();
    let stack = start_live(eng, &[models::GP_NAME], "slurm", 2, 5000.0, true,
                           LivePolicy::Fcfs)
        .expect("live stack");
    let mut client = HttpModel::connect(&stack.balancer.url(),
                                        models::GP_NAME)
        .expect("client");
    let cfg = Value::Obj(Default::default());
    let out = client
        .evaluate(&[lhs(1, 4)[0].to_vec()], &cfg)
        .expect("evaluate");
    assert_eq!(out[0].len(), 2);
}

#[test]
fn balancer_per_job_servers_retire() {
    // The paper's measured configuration: one evaluation per server.
    let eng = need_artifacts!();
    let stack = start_live(eng, &[models::GP_NAME], "hq", 2, 5000.0, false,
                           LivePolicy::Fcfs)
        .expect("live stack");
    let mut client = HttpModel::connect(&stack.balancer.url(),
                                        models::GP_NAME)
        .expect("client");
    let cfg = Value::Obj(Default::default());
    for p in lhs(4, 5) {
        let out = client.evaluate(&[p.to_vec()], &cfg).expect("evaluate");
        assert_eq!(out[0].len(), 2);
    }
    // Servers were spawned repeatedly (retired after each evaluation).
    assert!(stack.balancer.registry().registered_total() >= 3,
            "expected several registrations, got {}",
            stack.balancer.registry().registered_total());
}

#[test]
fn balancer_multi_model_real_models() {
    // Two heterogeneous PJRT models behind one front door: contracts
    // learned at registration, /Evaluate routed by name.
    let eng = need_artifacts!();
    let stack = start_live(eng, &[models::GP_NAME, models::EIGEN_SMALL_NAME],
                           "hq", 2, 5000.0, true,
                           LivePolicy::Fcfs)
        .expect("live stack");
    let url = stack.balancer.url();
    let cfg = Value::Obj(Default::default());

    let mut gp = HttpModel::connect(&url, models::GP_NAME).expect("gp client");
    let mut eig = HttpModel::connect(&url, models::EIGEN_SMALL_NAME)
        .expect("eigen client");
    for p in &lhs(3, 11) {
        let out = gp.evaluate(&[p.to_vec()], &cfg).expect("gp evaluate");
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[1].len(), 2);
    }
    let out = eig.evaluate(&[vec![42.0]], &cfg).expect("eigen evaluate");
    assert_eq!(out[0].len(), 100);
    // Contracts were learned per model, not from a static table.
    assert_eq!(gp.input_sizes().expect("gp sizes"), vec![7]);
    assert_eq!(eig.output_sizes().expect("eigen sizes"), vec![100, 1]);
    // /Info aggregates both models.
    let (_ver, names) = gp.info().expect("info");
    assert!(names.contains(&models::GP_NAME.to_string()));
    assert!(names.contains(&models::EIGEN_SMALL_NAME.to_string()));
}

#[test]
fn balancer_concurrent_clients_fcfs() {
    let eng = need_artifacts!();
    let stack = start_live(eng, &[models::GP_NAME], "hq", 3, 5000.0, true,
                           LivePolicy::Fcfs)
        .expect("live stack");
    let url = stack.balancer.url();
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let url = url.clone();
            std::thread::spawn(move || {
                let mut c = HttpModel::connect(&url, models::GP_NAME)
                    .expect("client");
                let cfg = Value::Obj(Default::default());
                for (i, p) in lhs(5, t).iter().enumerate() {
                    let out = c.evaluate(&[p.to_vec()], &cfg)
                        .unwrap_or_else(|e| panic!("t{t} i{i}: {e:#}"));
                    assert_eq!(out[0].len(), 2);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert!(stack.balancer.requests_served
                .load(std::sync::atomic::Ordering::Relaxed) >= 20);
}
