//! Differential cross-core fuzz harness: seeded random event scripts
//! (submit / cancel / complete / fail / worker-up / worker-lost / timer
//! interleavings) driven through ALL five scheduler cores via the
//! generic `SchedulerCore` seam, checking the structural invariants no
//! correct scheduler may break:
//!
//! * no task is lost — every submitted evaluation reaches exactly one
//!   terminal record (normal, truncated, cancelled or quarantined);
//! * no task double-starts — every `Effect::Start` is matched by a
//!   `Finish` or `Requeued` before the next `Start` of the same id;
//! * timers never act on evicted ids — a stale timer is either reported
//!   stale by `timer_is_stale` or is a no-op (it must not resurrect a
//!   finished task);
//! * the five cores agree on the terminal tag set for the same script
//!   (the differential part — schedulers order work differently, but
//!   none may drop or duplicate an evaluation the others retire).
//!
//! A failing script is shrunk by greedy one-op removal to a minimal
//! repro and printed together with its seed.  The case count defaults
//! to 200 and is overridable with `CORE_FUZZ_CASES`.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use uqsched::campaign::{CampaignConfig, SlurmMode, Submission};
use uqsched::clock::{Des, Micros, SEC};
use uqsched::cluster::ClusterSpec;
use uqsched::hqlite::HqCore;
use uqsched::sched::{CapacityChange, Completion, EdfCore, Effect, GangCore,
                     MetaStack, SchedulerCore, SlurmSched, WorkStealCore};
use uqsched::util::Rng;
use uqsched::workload::App;

/// One abstract script operation, core-agnostic: `nth` indexes the
/// submissions in script order, so the same script addresses the same
/// logical work on every core regardless of its id space.
#[derive(Clone, Debug)]
enum Op {
    Submit { duration: Micros },
    Cancel { nth: usize },
    Fail { nth: usize, retry: Option<Micros> },
    WorkerUp { id: u64, cores: u32 },
    WorkerLost { id: u64 },
}

type Script = Vec<(Micros, Op)>;

fn gen_script(rng: &mut Rng) -> Script {
    let n_ops = 5 + rng.below(25) as usize;
    let mut script: Script = Vec::with_capacity(n_ops + 1);
    let mut submits = 0usize;
    for _ in 0..n_ops {
        let t = rng.below(120) * SEC;
        let op = match rng.below(10) {
            0..=4 => {
                submits += 1;
                Op::Submit { duration: (1 + rng.below(8)) * SEC }
            }
            5 => Op::Cancel { nth: rng.below(12) as usize },
            6 | 7 => Op::Fail {
                nth: rng.below(12) as usize,
                retry: if rng.uniform() < 0.5 {
                    Some((1 + rng.below(3)) * SEC)
                } else {
                    None
                },
            },
            8 => Op::WorkerUp { id: 100 + rng.below(4), cores: 16 },
            // `id` is an abstract victim draw, resolved against the
            // live worker pool at fire time (core id spaces differ:
            // live cores use the announced ids, stacks use internal
            // generational slab ids).
            _ => Op::WorkerLost { id: rng.below(8) },
        };
        script.push((t, op));
    }
    if submits == 0 {
        script.push((0, Op::Submit { duration: SEC }));
    }
    script.sort_by_key(|(t, _)| *t);
    script
}

fn fmt_script(script: &Script) -> String {
    script
        .iter()
        .map(|(t, op)| format!("  t={:>4}s {op:?}", t / SEC))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Per-submission bookkeeping in the generic driver.
struct Work<I> {
    id: I,
    /// Driver-owned workload duration returned by `submit_into`.
    dur: Micros,
    /// An `Effect::Start` is open (no `Finish`/`Requeued` yet).
    running: bool,
    /// A terminal record was observed.
    finished: bool,
    /// Attempt counter; a pending work-done from a previous attempt is
    /// stale once this moves (mirrors the production kernel's epochs).
    epoch: u64,
}

/// Drive one core through the script with a miniature DES, checking
/// invariants at every transition.  Returns the sorted terminal
/// evaluation tags.
fn run_script<S: SchedulerCore>(core: &mut S, script: &Script) -> Vec<u64> {
    enum Ev<T> {
        Op(usize),
        Timer(T),
        WorkDone { nth: usize, epoch: u64 },
    }
    let label = core.label();
    let mut des: Des<Ev<S::Timer>> = Des::new();
    for (i, (t, _)) in script.iter().enumerate() {
        des.schedule(*t, Ev::Op(i));
    }
    let mut works: Vec<Work<S::Id>> = Vec::new();
    let mut by_id: HashMap<S::Id, usize> = HashMap::new();
    let mut tags: Vec<u64> = Vec::new();
    let mut effects: Vec<Effect<S::Id, S::Timer>> = Vec::new();
    let mut ops_left = script.len();
    let mut now: Micros = 0;
    core.bootstrap_into(0, &mut effects);
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 2_000_000,
                "{label}: runaway fuzz script (task lost or livelock)");
        for e in effects.drain(..) {
            match e {
                Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Effect::Start { id, contention, workers } => {
                    // Work the driver did not submit (none expected with
                    // background load and registrations disabled) would
                    // be ignored, mirroring the production kernel.
                    let Some(&nth) = by_id.get(&id) else { continue };
                    let w = &mut works[nth];
                    assert!(!w.finished,
                            "{label}: Start for evicted task #{nth}");
                    assert!(!w.running,
                            "{label}: double Start without Requeued for \
                             task #{nth}");
                    let members = workers.ids();
                    let mut uniq = members.to_vec();
                    uniq.sort_unstable();
                    uniq.dedup();
                    assert_eq!(uniq.len(), members.len(),
                               "{label}: duplicate members in placement \
                                {members:?} for task #{nth}");
                    w.running = true;
                    w.epoch += 1;
                    let dd = (w.dur as f64 * contention) as Micros;
                    des.schedule(now + dd,
                                 Ev::WorkDone { nth, epoch: w.epoch });
                }
                Effect::Requeued { id } => {
                    let Some(&nth) = by_id.get(&id) else { continue };
                    let w = &mut works[nth];
                    assert!(!w.finished,
                            "{label}: Requeued after Finish for task #{nth}");
                    w.running = false;
                    w.epoch += 1;
                }
                Effect::Finish { id, record } => {
                    match core.classify(&record) {
                        Completion::Evaluation => {
                            let Some(&nth) = by_id.get(&id) else {
                                panic!("{label}: evaluation record for \
                                        unknown work")
                            };
                            let w = &mut works[nth];
                            assert!(!w.finished,
                                    "{label}: double Finish for task #{nth}");
                            w.finished = true;
                            w.running = false;
                            tags.push(record.tag);
                        }
                        Completion::Registration
                        | Completion::Background => {}
                    }
                }
                Effect::Retire { .. }
                | Effect::Queued
                | Effect::Released { .. } => {}
            }
        }
        if ops_left == 0 && works.iter().all(|w| w.finished) {
            break;
        }
        let Some((t, ev)) = des.pop() else { break };
        now = t;
        match ev {
            Ev::Op(i) => {
                ops_left -= 1;
                match &script[i].1 {
                    Op::Submit { duration } => {
                        let tag = works.len() as u64;
                        let s = Submission {
                            tag,
                            user: 0,
                            app: App::Gp,
                            duration: *duration,
                        };
                        let (id, dur) = core.submit_into(t, &s, &mut effects);
                        by_id.insert(id, works.len());
                        works.push(Work {
                            id,
                            dur,
                            running: false,
                            finished: false,
                            epoch: 0,
                        });
                    }
                    Op::Cancel { nth } => {
                        // Cancel in any state — including already
                        // finished (must be a no-op) and cores that do
                        // not support cancel (documented no-op).
                        if let Some(w) = works.get(*nth) {
                            core.cancel_into(t, w.id, &mut effects);
                        }
                    }
                    Op::Fail { nth, retry } => {
                        // In-contract fault injection: the seam defines
                        // failure as "failed mid-run", so only a
                        // currently running attempt can fail (exactly
                        // when the production fault plane injects).
                        if let Some(w) = works.get(*nth) {
                            if w.running && !w.finished {
                                core.on_work_failed_into(
                                    t, w.id, *retry, &mut effects,
                                );
                            }
                        }
                    }
                    Op::WorkerUp { id, cores } => {
                        core.on_capacity_change_into(
                            t,
                            CapacityChange::WorkerUp {
                                id: *id,
                                cores: *cores,
                            },
                            &mut effects,
                        );
                    }
                    Op::WorkerLost { id } => {
                        // Resolve the abstract victim draw against the
                        // pool that is actually live NOW (exactly how
                        // the fault plane picks crash victims).  An
                        // empty pool passes the raw draw through,
                        // exercising the unknown-worker no-op path.
                        let mut live = Vec::new();
                        core.live_worker_ids(&mut live);
                        live.sort_unstable();
                        live.dedup();
                        let wid = if live.is_empty() {
                            *id
                        } else {
                            live[*id as usize % live.len()]
                        };
                        core.on_capacity_change_into(
                            t,
                            CapacityChange::WorkerLost(wid),
                            &mut effects,
                        );
                    }
                }
            }
            Ev::Timer(tm) => {
                // The kernel contract: stale timers are skipped at pop;
                // live ones are delivered.  A delivered timer acting on
                // an evicted id trips the Start/Finish assertions above.
                if !core.timer_is_stale(&tm) {
                    core.on_timer_into(t, tm, &mut effects);
                }
            }
            Ev::WorkDone { nth, epoch } => {
                let w = &works[nth];
                if !w.finished && w.running && w.epoch == epoch {
                    core.on_work_done_into(t, w.id, &mut effects);
                }
            }
        }
    }
    assert_eq!(ops_left, 0, "{label}: script not fully delivered");
    for (nth, w) in works.iter().enumerate() {
        assert!(w.finished,
                "{label}: task #{nth} lost — no terminal record");
    }
    // Generation safety: every retired id is dead for good.  Scripts
    // routinely evict early tasks and then submit more, so under the
    // slab cores later ids sit in *recycled slots* of earlier ones —
    // replaying the full stale-capable op surface with the evicted ids
    // must emit nothing for them.  A slot reuse that resurrected the
    // old generation would surface here as a Start/Finish/Requeued for
    // a known (finished) id.
    effects.clear();
    for w in &works {
        core.cancel_into(now, w.id, &mut effects);
        core.on_work_done_into(now, w.id, &mut effects);
        core.on_work_failed_into(now, w.id, None, &mut effects);
        core.on_work_failed_into(now, w.id, Some(SEC), &mut effects);
    }
    for e in &effects {
        let id = match e {
            Effect::Start { id, .. }
            | Effect::Requeued { id }
            | Effect::Finish { id, .. } => id,
            _ => continue,
        };
        let nth = by_id.get(id);
        assert!(nth.is_none(),
                "{label}: evicted task #{nth:?} resurrected by a stale \
                 replay (generation safety broken)");
    }
    tags.sort_unstable();
    let n = tags.len();
    tags.dedup();
    assert_eq!(tags.len(), n, "{label}: duplicate terminal tags");
    tags
}

/// One script through all five cores; panics on any invariant breach or
/// cross-core terminal-set divergence.
fn run_all_cores(core_seed: u64, script: &Script) {
    let mut ccfg = CampaignConfig::paper(App::Gp, 2, core_seed);
    ccfg.cluster = ClusterSpec::small(8);
    // Quiet cluster, no registration pre-jobs: every Start/Finish the
    // harness sees belongs to script work.
    ccfg.overheads.bg_interarrival = Micros::MAX;
    ccfg.registration_jobs = 0;

    let mut tagsets: Vec<(&'static str, Vec<u64>)> = Vec::new();
    {
        let mut core = SlurmSched::new(&ccfg, SlurmMode::Native);
        tagsets.push(("slurm", run_script(&mut core, script)));
    }
    {
        let mut core =
            MetaStack::new(&ccfg, HqCore::new(ccfg.autoalloc()), "HQ");
        tagsets.push(("hq", run_script(&mut core, script)));
    }
    {
        let mut core = MetaStack::new(
            &ccfg,
            WorkStealCore::new(ccfg.autoalloc()),
            "worksteal",
        );
        tagsets.push(("worksteal", run_script(&mut core, script)));
    }
    {
        let mut core =
            MetaStack::new(&ccfg, EdfCore::new(ccfg.autoalloc()), "edf");
        tagsets.push(("edf", run_script(&mut core, script)));
    }
    {
        let mut core = MetaStack::new(
            &ccfg,
            GangCore::new(ccfg.autoalloc()).with_gang(1, 2),
            "gang",
        );
        tagsets.push(("gang", run_script(&mut core, script)));
    }
    let (first_label, first_tags) = &tagsets[0];
    for (label, tags) in &tagsets[1..] {
        assert_eq!(tags, first_tags,
                   "{label}: terminal tag set diverged from {first_label}");
    }
}

/// Did the script fail?  Returns the panic message when it did.
fn script_fails(core_seed: u64, script: &Script) -> Option<String> {
    catch_unwind(AssertUnwindSafe(|| run_all_cores(core_seed, script)))
        .err()
        .map(|e| {
            e.downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into())
        })
}

/// Greedy one-op-removal shrink: keep deleting any single op whose
/// removal preserves the failure, until no removal does.
fn shrink(core_seed: u64, mut script: Script) -> Script {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut i = 0;
    while i < script.len() && script.len() > 1 {
        let mut cand = script.clone();
        cand.remove(i);
        if script_fails(core_seed, &cand).is_some() {
            script = cand;
            i = 0; // a removal can unlock earlier removals: rescan
        } else {
            i += 1;
        }
    }
    std::panic::set_hook(prev);
    script
}

#[test]
fn fuzz_random_event_scripts_across_all_five_cores() {
    let cases: u64 = std::env::var("CORE_FUZZ_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    for case in 0..cases {
        let seed = 0x5EED_C0DE_0000u64.wrapping_add(case);
        let mut rng = Rng::new(seed);
        let script = gen_script(&mut rng);
        let core_seed = rng.next_u64();
        if let Some(msg) = script_fails(core_seed, &script) {
            let minimal = shrink(core_seed, script);
            let repro = script_fails(core_seed, &minimal)
                .unwrap_or_else(|| msg.clone());
            panic!(
                "core fuzz failed at case {case} (seed {seed:#x}): {msg}\n\
                 minimal repro ({} ops, shrunk failure: {repro}):\n{}",
                minimal.len(),
                fmt_script(&minimal),
            );
        }
    }
}

/// Deterministic generation-safety drill: wave one drains fully before
/// wave two submits, so on the slab-backed cores wave two's tasks (and
/// the workers admitted for them) recycle wave one's freed slots (LIFO
/// free list).  The post-drain stale replay in [`run_script`] then
/// replays *every* id — wave one's against slots owned by a newer
/// generation — and must observe pure no-ops on all five cores.
#[test]
fn stale_ids_after_slot_reuse_are_rejected_on_all_five_cores() {
    let mut script: Script = Vec::new();
    for _ in 0..3 {
        script.push((0, Op::Submit { duration: SEC }));
    }
    // Far enough out that wave one (including its allocation spin-up)
    // has fully retired and its slots sit on the free list.
    for _ in 0..3 {
        script.push((3600 * SEC, Op::Submit { duration: SEC }));
    }
    script.sort_by_key(|(t, _)| *t);
    run_all_cores(0xC0FFEE, &script);
}

// ---------------------------------------------------------------------------
// DAG script fuzz: seeded forests (plus deterministic diamonds, deep
// chains and wide fan-ins) submitted through the kernel's dependency
// layer (`Sink::submit_after` -> `DepTracker`) on all five cores.  The
// invariants no correct dependency plane may break:
//
// * the campaign drains — one record per submitted node, no deadlock;
// * no child starts before every parent's record ended;
// * a truncated parent poisons its descendants (skip cascade) — under
//   faults, a quarantined ancestor's subtree surfaces as truncated
//   records, never as lost work;
// * without faults, nothing truncates and the five cores retire the
//   identical tag set.
//
// The case count defaults to 20 and is overridable with
// `CORE_FUZZ_DAG_CASES`.
// ---------------------------------------------------------------------------

use uqsched::campaign::{self, Sink, Submitter};
use uqsched::metrics::JobRecord;
use uqsched::sched::FaultSpec;

/// A whole DAG pre-submitted at t = 0: node `i` is tag `i`, and its
/// parents all have smaller tags (generation guarantees acyclicity).
struct DagScriptSub {
    parents: Vec<Vec<u64>>,
    durations: Vec<Micros>,
    started: bool,
}

impl DagScriptSub {
    fn new(parents: Vec<Vec<u64>>, durations: Vec<Micros>) -> Self {
        assert_eq!(parents.len(), durations.len());
        DagScriptSub { parents, durations, started: false }
    }
}

impl Submitter for DagScriptSub {
    fn label(&self) -> &'static str {
        "dag-fuzz"
    }

    fn start(&mut self, sink: &mut Sink) {
        self.started = true;
        for (i, ps) in self.parents.iter().enumerate() {
            let s = Submission {
                tag: i as u64,
                user: 0,
                app: App::Gp,
                duration: self.durations[i],
            };
            if ps.is_empty() {
                sink.submit(s);
            } else {
                sink.submit_after(s, ps);
            }
        }
    }

    fn wake(&mut self, _t: Micros, _token: u64, _sink: &mut Sink) {}

    fn completed(&mut self, _t: Micros, _rec: &JobRecord, _sink: &mut Sink) {}

    fn finished(&self, completed: u64) -> bool {
        self.started && completed >= self.parents.len() as u64
    }
}

/// Random forest: ~70% of non-first nodes draw 1..=3 distinct parents
/// among earlier nodes, the rest are roots — covers disconnected trees,
/// diamonds and deep paths in one generator.
fn gen_dag(rng: &mut Rng) -> (Vec<Vec<u64>>, Vec<Micros>) {
    let n = 4 + rng.below(40) as usize;
    let mut parents: Vec<Vec<u64>> = Vec::with_capacity(n);
    let mut durations: Vec<Micros> = Vec::with_capacity(n);
    for i in 0..n {
        let mut ps: Vec<u64> = Vec::new();
        if i > 0 && rng.uniform() < 0.7 {
            let k = 1 + rng.below(3.min(i as u64));
            for _ in 0..k {
                let p = rng.below(i as u64);
                if !ps.contains(&p) {
                    ps.push(p);
                }
            }
        }
        parents.push(ps);
        durations.push((1 + rng.below(5)) * SEC);
    }
    (parents, durations)
}

fn dag_cfg(faults: Option<FaultSpec>) -> CampaignConfig {
    let mut cfg = CampaignConfig::paper(App::Gp, 2, 9);
    cfg.cluster = ClusterSpec::small(8);
    cfg.overheads.bg_interarrival = 300 * SEC;
    cfg.registration_jobs = 0;
    cfg.faults = faults;
    cfg
}

/// Drive the DAG through all five cores; return per-core records.
fn run_dag_all_cores(
    parents: &[Vec<u64>],
    durations: &[Micros],
    faults: Option<FaultSpec>,
) -> Vec<(&'static str, Vec<JobRecord>)> {
    let cfg = dag_cfg(faults);
    let mut out = Vec::new();
    for which in ["slurm", "hq", "worksteal", "edf", "gang"] {
        let mut sub =
            DagScriptSub::new(parents.to_vec(), durations.to_vec());
        let res = match which {
            "slurm" => campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native),
            "hq" => campaign::run_hq(&cfg, &mut sub),
            "worksteal" => campaign::run_worksteal(&cfg, &mut sub),
            "gang" => campaign::run_gang(&cfg, &mut sub),
            _ => campaign::run_edf(&cfg, &mut sub),
        };
        out.push((which, res.experiment.records));
    }
    out
}

/// The per-core structural invariants: drain, edge ordering, skip
/// cascade.  `clean` additionally forbids truncation outright.
fn check_dag_invariants(
    label: &str,
    parents: &[Vec<u64>],
    runs: &[(&'static str, Vec<JobRecord>)],
    clean: bool,
) {
    let n = parents.len();
    for (name, recs) in runs {
        assert_eq!(
            recs.len(),
            n,
            "{label}/{name}: {} records for {} submitted nodes \
             (lost work or deadlock)",
            recs.len(),
            n
        );
        let mut by_tag: HashMap<u64, &JobRecord> = HashMap::new();
        for r in recs {
            assert!(
                by_tag.insert(r.tag, r).is_none(),
                "{label}/{name}: duplicate record for tag {}",
                r.tag
            );
            assert!((r.tag as usize) < n, "{label}/{name}: unknown tag");
            if clean {
                assert!(
                    !r.truncated,
                    "{label}/{name}: tag {} truncated without faults",
                    r.tag
                );
            }
        }
        for (child, ps) in parents.iter().enumerate() {
            let cr = by_tag[&(child as u64)];
            for p in ps {
                let pr = by_tag[p];
                assert!(
                    cr.start >= pr.end,
                    "{label}/{name}: child {child} started at {} before \
                     parent {p} ended at {}",
                    cr.start,
                    pr.end
                );
                assert!(
                    !pr.truncated || cr.truncated,
                    "{label}/{name}: child {child} ran although parent \
                     {p} was truncated (skip cascade broken)"
                );
            }
        }
    }
    // Differential part: every core retires the identical tag set.
    let tags = |recs: &[JobRecord]| -> Vec<u64> {
        let mut t: Vec<u64> = recs.iter().map(|r| r.tag).collect();
        t.sort_unstable();
        t
    };
    let first = tags(&runs[0].1);
    for (name, recs) in &runs[1..] {
        assert_eq!(
            tags(recs),
            first,
            "{label}/{name}: terminal tag set diverges from {}",
            runs[0].0
        );
    }
}

#[test]
fn dag_diamond_and_deep_chain_release_in_order_on_all_cores() {
    // Diamond: 0 -> {1, 2} -> 3.
    let diamond: Vec<Vec<u64>> =
        vec![vec![], vec![0], vec![0], vec![1, 2]];
    let durs = vec![2 * SEC; 4];
    let runs = run_dag_all_cores(&diamond, &durs, None);
    check_dag_invariants("diamond", &diamond, &runs, true);

    // 64-deep chain: strictly serial no matter how wide the cluster.
    let chain: Vec<Vec<u64>> =
        (0..64).map(|i| if i == 0 { vec![] } else { vec![i - 1] }).collect();
    let durs = vec![SEC; 64];
    let runs = run_dag_all_cores(&chain, &durs, None);
    check_dag_invariants("chain", &chain, &runs, true);
    for (name, recs) in &runs {
        let mut by_tag: HashMap<u64, &JobRecord> = HashMap::new();
        for r in recs {
            by_tag.insert(r.tag, r);
        }
        // The chain's serial lower bound: 64 tasks x 1 s.
        let last = by_tag[&63];
        assert!(
            last.end - by_tag[&0].start >= 64 * SEC,
            "{name}: 64-deep chain finished impossibly fast"
        );
    }

    // Wide fan-in: 16 independent parents join into one reduce.
    let mut fanin: Vec<Vec<u64>> = (0..16).map(|_| vec![]).collect();
    fanin.push((0..16).collect());
    let durs = vec![SEC; 17];
    let runs = run_dag_all_cores(&fanin, &durs, None);
    check_dag_invariants("fanin", &fanin, &runs, true);
}

#[test]
fn fuzz_random_dags_across_all_five_cores() {
    let cases: u64 = std::env::var("CORE_FUZZ_DAG_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);
    for case in 0..cases {
        let seed = 0xDA6_5EED_0000u64.wrapping_add(case);
        let mut rng = Rng::new(seed);
        let (parents, durations) = gen_dag(&mut rng);
        let runs = run_dag_all_cores(&parents, &durations, None);
        check_dag_invariants(
            &format!("case {case} (seed {seed:#x})"),
            &parents,
            &runs,
            true,
        );
    }
}

#[test]
fn fuzz_random_dags_under_faults_never_lose_work() {
    let cases: u64 = std::env::var("CORE_FUZZ_DAG_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20)
        .min(8);
    let spec = FaultSpec::parse(
        "crash=60s,fail=0.25,attempts=2,backoff=1s:8s,seed=7",
    )
    .expect("fault spec");
    for case in 0..cases {
        let seed = 0xFA17_DA60u64.wrapping_add(case);
        let mut rng = Rng::new(seed);
        let (parents, durations) = gen_dag(&mut rng);
        let runs =
            run_dag_all_cores(&parents, &durations, Some(spec.clone()));
        // Straggler slowdowns are keyed per (tag, attempt), so which
        // task quarantines CAN differ across cores — the per-core
        // invariants (drain, edge order, skip cascade) must not.
        check_dag_invariants(
            &format!("faulted case {case} (seed {seed:#x})"),
            &parents,
            &runs,
            false,
        );
    }
}
