//! Differential cross-core fuzz harness: seeded random event scripts
//! (submit / cancel / complete / fail / worker-up / worker-lost / timer
//! interleavings) driven through ALL five scheduler cores via the
//! generic `SchedulerCore` seam, checking the structural invariants no
//! correct scheduler may break:
//!
//! * no task is lost — every submitted evaluation reaches exactly one
//!   terminal record (normal, truncated, cancelled or quarantined);
//! * no task double-starts — every `Effect::Start` is matched by a
//!   `Finish` or `Requeued` before the next `Start` of the same id;
//! * timers never act on evicted ids — a stale timer is either reported
//!   stale by `timer_is_stale` or is a no-op (it must not resurrect a
//!   finished task);
//! * the five cores agree on the terminal tag set for the same script
//!   (the differential part — schedulers order work differently, but
//!   none may drop or duplicate an evaluation the others retire).
//!
//! A failing script is shrunk by greedy one-op removal to a minimal
//! repro and printed together with its seed.  The case count defaults
//! to 200 and is overridable with `CORE_FUZZ_CASES`.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use uqsched::campaign::{CampaignConfig, SlurmMode, Submission};
use uqsched::clock::{Des, Micros, SEC};
use uqsched::cluster::ClusterSpec;
use uqsched::hqlite::HqCore;
use uqsched::sched::{CapacityChange, Completion, EdfCore, Effect, GangCore,
                     MetaStack, SchedulerCore, SlurmSched, WorkStealCore};
use uqsched::util::Rng;
use uqsched::workload::App;

/// One abstract script operation, core-agnostic: `nth` indexes the
/// submissions in script order, so the same script addresses the same
/// logical work on every core regardless of its id space.
#[derive(Clone, Debug)]
enum Op {
    Submit { duration: Micros },
    Cancel { nth: usize },
    Fail { nth: usize, retry: Option<Micros> },
    WorkerUp { id: u64, cores: u32 },
    WorkerLost { id: u64 },
}

type Script = Vec<(Micros, Op)>;

fn gen_script(rng: &mut Rng) -> Script {
    let n_ops = 5 + rng.below(25) as usize;
    let mut script: Script = Vec::with_capacity(n_ops + 1);
    let mut submits = 0usize;
    for _ in 0..n_ops {
        let t = rng.below(120) * SEC;
        let op = match rng.below(10) {
            0..=4 => {
                submits += 1;
                Op::Submit { duration: (1 + rng.below(8)) * SEC }
            }
            5 => Op::Cancel { nth: rng.below(12) as usize },
            6 | 7 => Op::Fail {
                nth: rng.below(12) as usize,
                retry: if rng.uniform() < 0.5 {
                    Some((1 + rng.below(3)) * SEC)
                } else {
                    None
                },
            },
            8 => Op::WorkerUp { id: 100 + rng.below(4), cores: 16 },
            _ => Op::WorkerLost { id: 1 + rng.below(6) },
        };
        script.push((t, op));
    }
    if submits == 0 {
        script.push((0, Op::Submit { duration: SEC }));
    }
    script.sort_by_key(|(t, _)| *t);
    script
}

fn fmt_script(script: &Script) -> String {
    script
        .iter()
        .map(|(t, op)| format!("  t={:>4}s {op:?}", t / SEC))
        .collect::<Vec<_>>()
        .join("\n")
}

/// Per-submission bookkeeping in the generic driver.
struct Work<I> {
    id: I,
    /// Driver-owned workload duration returned by `submit_into`.
    dur: Micros,
    /// An `Effect::Start` is open (no `Finish`/`Requeued` yet).
    running: bool,
    /// A terminal record was observed.
    finished: bool,
    /// Attempt counter; a pending work-done from a previous attempt is
    /// stale once this moves (mirrors the production kernel's epochs).
    epoch: u64,
}

/// Drive one core through the script with a miniature DES, checking
/// invariants at every transition.  Returns the sorted terminal
/// evaluation tags.
fn run_script<S: SchedulerCore>(core: &mut S, script: &Script) -> Vec<u64> {
    enum Ev<T> {
        Op(usize),
        Timer(T),
        WorkDone { nth: usize, epoch: u64 },
    }
    let label = core.label();
    let mut des: Des<Ev<S::Timer>> = Des::new();
    for (i, (t, _)) in script.iter().enumerate() {
        des.schedule(*t, Ev::Op(i));
    }
    let mut works: Vec<Work<S::Id>> = Vec::new();
    let mut by_id: HashMap<S::Id, usize> = HashMap::new();
    let mut tags: Vec<u64> = Vec::new();
    let mut effects: Vec<Effect<S::Id, S::Timer>> = Vec::new();
    let mut ops_left = script.len();
    let mut now: Micros = 0;
    core.bootstrap_into(0, &mut effects);
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 2_000_000,
                "{label}: runaway fuzz script (task lost or livelock)");
        for e in effects.drain(..) {
            match e {
                Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Effect::Start { id, contention, workers } => {
                    // Work the driver did not submit (none expected with
                    // background load and registrations disabled) would
                    // be ignored, mirroring the production kernel.
                    let Some(&nth) = by_id.get(&id) else { continue };
                    let w = &mut works[nth];
                    assert!(!w.finished,
                            "{label}: Start for evicted task #{nth}");
                    assert!(!w.running,
                            "{label}: double Start without Requeued for \
                             task #{nth}");
                    let members = workers.ids();
                    let mut uniq = members.to_vec();
                    uniq.sort_unstable();
                    uniq.dedup();
                    assert_eq!(uniq.len(), members.len(),
                               "{label}: duplicate members in placement \
                                {members:?} for task #{nth}");
                    w.running = true;
                    w.epoch += 1;
                    let dd = (w.dur as f64 * contention) as Micros;
                    des.schedule(now + dd,
                                 Ev::WorkDone { nth, epoch: w.epoch });
                }
                Effect::Requeued { id } => {
                    let Some(&nth) = by_id.get(&id) else { continue };
                    let w = &mut works[nth];
                    assert!(!w.finished,
                            "{label}: Requeued after Finish for task #{nth}");
                    w.running = false;
                    w.epoch += 1;
                }
                Effect::Finish { id, record } => {
                    match core.classify(&record) {
                        Completion::Evaluation => {
                            let Some(&nth) = by_id.get(&id) else {
                                panic!("{label}: evaluation record for \
                                        unknown work")
                            };
                            let w = &mut works[nth];
                            assert!(!w.finished,
                                    "{label}: double Finish for task #{nth}");
                            w.finished = true;
                            w.running = false;
                            tags.push(record.tag);
                        }
                        Completion::Registration
                        | Completion::Background => {}
                    }
                }
                Effect::Retire { .. } | Effect::Queued => {}
            }
        }
        if ops_left == 0 && works.iter().all(|w| w.finished) {
            break;
        }
        let Some((t, ev)) = des.pop() else { break };
        now = t;
        match ev {
            Ev::Op(i) => {
                ops_left -= 1;
                match &script[i].1 {
                    Op::Submit { duration } => {
                        let tag = works.len() as u64;
                        let s = Submission {
                            tag,
                            user: 0,
                            app: App::Gp,
                            duration: *duration,
                        };
                        let (id, dur) = core.submit_into(t, &s, &mut effects);
                        by_id.insert(id, works.len());
                        works.push(Work {
                            id,
                            dur,
                            running: false,
                            finished: false,
                            epoch: 0,
                        });
                    }
                    Op::Cancel { nth } => {
                        // Cancel in any state — including already
                        // finished (must be a no-op) and cores that do
                        // not support cancel (documented no-op).
                        if let Some(w) = works.get(*nth) {
                            core.cancel_into(t, w.id, &mut effects);
                        }
                    }
                    Op::Fail { nth, retry } => {
                        // In-contract fault injection: the seam defines
                        // failure as "failed mid-run", so only a
                        // currently running attempt can fail (exactly
                        // when the production fault plane injects).
                        if let Some(w) = works.get(*nth) {
                            if w.running && !w.finished {
                                core.on_work_failed_into(
                                    t, w.id, *retry, &mut effects,
                                );
                            }
                        }
                    }
                    Op::WorkerUp { id, cores } => {
                        core.on_capacity_change_into(
                            t,
                            CapacityChange::WorkerUp {
                                id: *id,
                                cores: *cores,
                            },
                            &mut effects,
                        );
                    }
                    Op::WorkerLost { id } => {
                        core.on_capacity_change_into(
                            t,
                            CapacityChange::WorkerLost(*id),
                            &mut effects,
                        );
                    }
                }
            }
            Ev::Timer(tm) => {
                // The kernel contract: stale timers are skipped at pop;
                // live ones are delivered.  A delivered timer acting on
                // an evicted id trips the Start/Finish assertions above.
                if !core.timer_is_stale(&tm) {
                    core.on_timer_into(t, tm, &mut effects);
                }
            }
            Ev::WorkDone { nth, epoch } => {
                let w = &works[nth];
                if !w.finished && w.running && w.epoch == epoch {
                    core.on_work_done_into(t, w.id, &mut effects);
                }
            }
        }
    }
    assert_eq!(ops_left, 0, "{label}: script not fully delivered");
    for (nth, w) in works.iter().enumerate() {
        assert!(w.finished,
                "{label}: task #{nth} lost — no terminal record");
    }
    tags.sort_unstable();
    let n = tags.len();
    tags.dedup();
    assert_eq!(tags.len(), n, "{label}: duplicate terminal tags");
    tags
}

/// One script through all five cores; panics on any invariant breach or
/// cross-core terminal-set divergence.
fn run_all_cores(core_seed: u64, script: &Script) {
    let mut ccfg = CampaignConfig::paper(App::Gp, 2, core_seed);
    ccfg.cluster = ClusterSpec::small(8);
    // Quiet cluster, no registration pre-jobs: every Start/Finish the
    // harness sees belongs to script work.
    ccfg.overheads.bg_interarrival = Micros::MAX;
    ccfg.registration_jobs = 0;

    let mut tagsets: Vec<(&'static str, Vec<u64>)> = Vec::new();
    {
        let mut core = SlurmSched::new(&ccfg, SlurmMode::Native);
        tagsets.push(("slurm", run_script(&mut core, script)));
    }
    {
        let mut core =
            MetaStack::new(&ccfg, HqCore::new(ccfg.autoalloc()), "HQ");
        tagsets.push(("hq", run_script(&mut core, script)));
    }
    {
        let mut core = MetaStack::new(
            &ccfg,
            WorkStealCore::new(ccfg.autoalloc()),
            "worksteal",
        );
        tagsets.push(("worksteal", run_script(&mut core, script)));
    }
    {
        let mut core =
            MetaStack::new(&ccfg, EdfCore::new(ccfg.autoalloc()), "edf");
        tagsets.push(("edf", run_script(&mut core, script)));
    }
    {
        let mut core = MetaStack::new(
            &ccfg,
            GangCore::new(ccfg.autoalloc()).with_gang(1, 2),
            "gang",
        );
        tagsets.push(("gang", run_script(&mut core, script)));
    }
    let (first_label, first_tags) = &tagsets[0];
    for (label, tags) in &tagsets[1..] {
        assert_eq!(tags, first_tags,
                   "{label}: terminal tag set diverged from {first_label}");
    }
}

/// Did the script fail?  Returns the panic message when it did.
fn script_fails(core_seed: u64, script: &Script) -> Option<String> {
    catch_unwind(AssertUnwindSafe(|| run_all_cores(core_seed, script)))
        .err()
        .map(|e| {
            e.downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into())
        })
}

/// Greedy one-op-removal shrink: keep deleting any single op whose
/// removal preserves the failure, until no removal does.
fn shrink(core_seed: u64, mut script: Script) -> Script {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut i = 0;
    while i < script.len() && script.len() > 1 {
        let mut cand = script.clone();
        cand.remove(i);
        if script_fails(core_seed, &cand).is_some() {
            script = cand;
            i = 0; // a removal can unlock earlier removals: rescan
        } else {
            i += 1;
        }
    }
    std::panic::set_hook(prev);
    script
}

#[test]
fn fuzz_random_event_scripts_across_all_five_cores() {
    let cases: u64 = std::env::var("CORE_FUZZ_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    for case in 0..cases {
        let seed = 0x5EED_C0DE_0000u64.wrapping_add(case);
        let mut rng = Rng::new(seed);
        let script = gen_script(&mut rng);
        let core_seed = rng.next_u64();
        if let Some(msg) = script_fails(core_seed, &script) {
            let minimal = shrink(core_seed, script);
            let repro = script_fails(core_seed, &minimal)
                .unwrap_or_else(|| msg.clone());
            panic!(
                "core fuzz failed at case {case} (seed {seed:#x}): {msg}\n\
                 minimal repro ({} ops, shrunk failure: {repro}):\n{}",
                minimal.len(),
                fmt_script(&minimal),
            );
        }
    }
}
