//! Property tests on scheduler invariants (util::prop harness): random
//! workloads through the sim-plane experiment runners must satisfy the
//! structural properties of correct scheduling regardless of seed —
//! plus observational-equivalence tests pinning the indexed scheduler
//! cores to the seed semantics preserved in the `reference` modules,
//! plus pluggability tests running all five schedulers generically
//! through one `SchedulerCore` harness, pinning the work-stealing
//! core's no-task-lost / FIFO-deque invariants under worker churn, the
//! EDF core's pop-order / no-starvation / determinism invariants, and
//! the gang core's no-partial-gang invariant under worker churn.

use std::collections::HashMap;

use uqsched::campaign::{run_edf, run_gang, run_hq, run_slurm, run_worksteal,
                        CampaignConfig, CampaignResult, FixedDepth,
                        SlurmMode, Submission};
use uqsched::cluster::{ClusterSpec, JobRequest, OverheadModel};
use uqsched::clock::{Des, Micros, MS, SEC};
use uqsched::experiments::{run_naive_slurm, run_umbridge_hq,
                           run_umbridge_slurm, Config};
use uqsched::hqlite::{AutoAllocConfig, HqAction, HqCore, HqTimer,
                      ReferenceHqCore, TaskCore, TaskId, TaskSpec};
use uqsched::metrics::JobRecord;
use uqsched::sched::{kernel, CapacityChange, EdfCore, Effect, FaultPlan,
                     FaultSpec, GangCore, MetaStack, SchedulerCore,
                     SlurmSched, StackTimer, WorkStealCore};
use uqsched::slurmlite::core::{Action, BatchCore, JobId, SlurmCore, Timer,
                               USER_EXPERIMENT};
use uqsched::slurmlite::ReferenceSlurmCore;
use uqsched::util::prop;
use uqsched::util::Rng;
use uqsched::workload::App;

fn random_cfg(rng: &mut uqsched::util::Rng) -> Config {
    let apps = App::all();
    let app = apps[rng.below(4) as usize];
    let qd = [1usize, 2, 3, 10][rng.below(4) as usize];
    let mut cfg = Config::paper(app, qd, rng.next_u64());
    cfg.n_evals = 5 + rng.below(15);
    cfg.cluster = ClusterSpec::small(4 + rng.below(8) as usize);
    // Mixed quiet/busy clusters.
    if rng.uniform() < 0.5 {
        cfg.overheads.bg_interarrival = Micros::MAX;
    } else {
        cfg.overheads.bg_interarrival = 100 * SEC;
    }
    cfg
}

#[test]
fn prop_all_evaluations_complete_exactly_once() {
    prop::check("complete-once", 12, |rng| {
        let cfg = random_cfg(rng);
        for exp in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg)] {
            assert_eq!(exp.records.len() as u64, cfg.n_evals,
                       "{}: wrong record count", exp.label);
            let mut tags: Vec<u64> =
                exp.records.iter().map(|r| r.tag).collect();
            tags.sort();
            tags.dedup();
            assert_eq!(tags.len() as u64, cfg.n_evals,
                       "{}: duplicated/lost tags", exp.label);
        }
    });
}

#[test]
fn prop_time_ordering_per_job() {
    prop::check("time-ordering", 12, |rng| {
        let cfg = random_cfg(rng);
        for exp in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg),
                    run_umbridge_slurm(&cfg)] {
            for r in &exp.records {
                assert!(r.submit <= r.start, "{}: submit > start",
                        exp.label);
                assert!(r.start <= r.end, "{}: start > end", exp.label);
                assert!(r.cpu <= r.makespan() + 1,
                        "{}: cpu {} > makespan {}", exp.label, r.cpu,
                        r.makespan());
            }
        }
    });
}

#[test]
fn prop_slr_at_least_one() {
    prop::check("slr>=1", 10, |rng| {
        let cfg = random_cfg(rng);
        for exp in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg)] {
            for r in &exp.records {
                assert!(r.slr() >= 1.0 - 1e-9, "{}: SLR {}", exp.label,
                        r.slr());
            }
            assert!(exp.slr() >= 0.0);
        }
    });
}

#[test]
fn prop_makespan_at_least_critical_path() {
    // The experiment makespan can never beat total work / parallelism.
    prop::check("critical-path", 8, |rng| {
        let mut cfg = random_cfg(rng);
        cfg.overheads.bg_interarrival = Micros::MAX; // isolate the bound
        let exp = run_naive_slurm(&cfg);
        let total_cpu: u64 = exp.records.iter().map(|r| r.cpu).sum();
        let lower = total_cpu / (cfg.queue_depth as u64).max(1);
        assert!(exp.makespan() + SEC >= lower,
                "makespan {} < critical path {}", exp.makespan(), lower);
    });
}

#[test]
fn prop_same_seed_same_records() {
    prop::check("determinism", 6, |rng| {
        let cfg = random_cfg(rng);
        let a = run_umbridge_hq(&cfg);
        let b = run_umbridge_hq(&cfg);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x, y);
        }
    });
}

// ---------------------------------------------------------------------------
// Observational equivalence: indexed cores vs seed-semantics references.
//
// The indexed cores (BTree pending lanes, failure frontiers, eviction)
// must be *behaviourally invisible*: on any trace, the exact same
// launches, timeouts and terminal records in the exact same order.
// ---------------------------------------------------------------------------

/// Uniform driver surface over the indexed and reference slurm cores.
trait SlurmLike {
    fn bootstrap(&mut self, t: Micros) -> Vec<Action>;
    fn submit(&mut self, t: Micros, user: u32, tag: u64, req: JobRequest)
              -> (JobId, Vec<Action>);
    fn cancel(&mut self, t: Micros, id: JobId) -> Vec<Action>;
    fn on_timer(&mut self, t: Micros, tm: Timer) -> Vec<Action>;
    fn on_finish(&mut self, t: Micros, id: JobId) -> Vec<Action>;
}

macro_rules! impl_slurm_like {
    ($ty:ty) => {
        impl SlurmLike for $ty {
            fn bootstrap(&mut self, t: Micros) -> Vec<Action> {
                <$ty>::bootstrap(self, t)
            }
            fn submit(&mut self, t: Micros, user: u32, tag: u64,
                      req: JobRequest) -> (JobId, Vec<Action>) {
                <$ty>::submit(self, t, user, tag, req)
            }
            fn cancel(&mut self, t: Micros, id: JobId) -> Vec<Action> {
                <$ty>::cancel(self, t, id)
            }
            fn on_timer(&mut self, t: Micros, tm: Timer) -> Vec<Action> {
                <$ty>::on_timer(self, t, tm)
            }
            fn on_finish(&mut self, t: Micros, id: JobId) -> Vec<Action> {
                <$ty>::on_finish(self, t, id)
            }
        }
    };
}

impl_slurm_like!(SlurmCore);
impl_slurm_like!(ReferenceSlurmCore);

/// One slurm trace operation at an absolute time.
#[derive(Clone, Debug)]
enum SlurmOp {
    /// Submit (request, workload duration).
    Submit(JobRequest, Micros),
    /// Cancel the n-th trace submission (scheduled after its submit).
    Cancel(usize),
}

/// Everything observable a slurm core emits while driving a trace.
#[derive(Debug, PartialEq, Default)]
struct SlurmObs {
    launches: Vec<(JobId, usize, u64)>, // (job, node, contention bits)
    timeouts: Vec<JobId>,
    records: Vec<(JobId, JobRecord)>,
}

fn drive_slurm_trace<C: SlurmLike>(
    core: &mut C,
    trace: &[(Micros, SlurmOp)],
) -> SlurmObs {
    #[derive(Debug)]
    enum Ev {
        Timer(Timer),
        Op(usize),
        Finish(JobId),
    }
    let n_submissions = trace
        .iter()
        .filter(|(_, op)| matches!(op, SlurmOp::Submit(..)))
        .count();
    let mut des: Des<Ev> = Des::new();
    for a in core.bootstrap(0) {
        if let Action::Timer(t, tm) = a {
            des.schedule(t, Ev::Timer(tm));
        }
    }
    for (i, (t, _)) in trace.iter().enumerate() {
        des.schedule(*t, Ev::Op(i));
    }
    let mut obs = SlurmObs::default();
    let mut durations: HashMap<JobId, Micros> = HashMap::new();
    let mut submission_ids: Vec<JobId> = Vec::new();
    let mut experiment_records = 0usize;
    let mut guard = 0u64;
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 2_000_000, "runaway equivalence trace");
        let acts = match ev {
            Ev::Timer(tm) => core.on_timer(t, tm),
            Ev::Op(i) => match &trace[i].1 {
                SlurmOp::Submit(req, dur) => {
                    let (id, acts) =
                        core.submit(t, USER_EXPERIMENT, 1 + *dur, *req);
                    durations.insert(id, *dur);
                    submission_ids.push(id);
                    acts
                }
                SlurmOp::Cancel(nth) => {
                    // Trace generation guarantees the submission fired.
                    let id = submission_ids[*nth];
                    core.cancel(t, id)
                }
            },
            Ev::Finish(id) => core.on_finish(t, id),
        };
        for a in acts {
            match a {
                Action::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Action::Launched { job, node, contention } => {
                    obs.launches.push((job, node, contention.to_bits()));
                    if let Some(d) = durations.get(&job) {
                        let dd = (*d as f64 * contention) as Micros;
                        des.schedule(t + dd, Ev::Finish(job));
                    }
                }
                Action::TimedOut { job } => obs.timeouts.push(job),
                Action::Completed { job, record } => {
                    if record.tag != u64::MAX {
                        experiment_records += 1;
                    }
                    obs.records.push((job, record));
                }
            }
        }
        if experiment_records >= n_submissions {
            break;
        }
    }
    assert_eq!(experiment_records, n_submissions, "trace did not complete");
    obs
}

/// Random trace: mixed shapes, staggered arrivals, some cancels, some
/// tight time limits; cluster and background load vary per case.
fn random_slurm_trace(
    rng: &mut Rng,
) -> (ClusterSpec, OverheadModel, Vec<(Micros, SlurmOp)>) {
    let cluster = ClusterSpec::small(1 + rng.below(6) as usize);
    let mut model = OverheadModel::quiet();
    if rng.uniform() < 0.4 {
        // Busy cluster: background stream exercises the bg paths (both
        // cores consume the RNG identically, so the load is identical).
        model.bg_interarrival = 20 * SEC;
        model.bg_duration = 60 * SEC;
        model.bg_cores = (1, 8);
    }
    if rng.uniform() < 0.3 {
        model.user_quota = 1 + rng.below(4) as u32;
        model.quota_penalty = (1 + rng.below(30)) * SEC;
    }
    if rng.uniform() < 0.3 {
        model.backfill_delay_factor = 0.02;
    }
    // Generate submissions first and sort them; their index in sorted
    // order is the index the driver's `submission_ids` will assign.
    let n = 5 + rng.below(25) as usize;
    let mut submits: Vec<(Micros, JobRequest, Micros)> = (0..n)
        .map(|_| {
            let t = rng.below(120) * SEC;
            // Shapes that always fit a small() node eventually.
            let cores = 1 + rng.below(16) as u32;
            let ram = 1 + rng.below(16) as u32;
            // Mostly generous limits, occasionally tight (timeout path).
            let limit = if rng.uniform() < 0.15 {
                (1 + rng.below(3)) * SEC
            } else {
                1000 * SEC
            };
            let dur = (1 + rng.below(20)) * SEC / 2;
            (t, JobRequest::new(cores, ram, limit), dur)
        })
        .collect();
    submits.sort_by_key(|(t, ..)| *t);
    let mut trace: Vec<(Micros, SlurmOp)> = submits
        .iter()
        .map(|(t, req, dur)| (*t, SlurmOp::Submit(*req, *dur)))
        .collect();
    for (i, (t, ..)) in submits.iter().enumerate() {
        if rng.uniform() < 0.25 {
            // Cancel strictly after the submission fires; cancellation in
            // any state (Submitting/Pending/Starting/Running/terminal) is
            // a valid point in the trace.
            let tc = t + 1 + rng.below(60 * SEC);
            trace.push((tc, SlurmOp::Cancel(i)));
        }
    }
    // Stable sort: a cancel tying with an unrelated submission keeps a
    // deterministic order; its own submission is strictly earlier.
    trace.sort_by_key(|(t, _)| *t);
    (cluster, model, trace)
}

#[test]
fn prop_indexed_slurm_core_equals_reference() {
    prop::check("slurm-indexed-equivalence", 16, |rng| {
        let (cluster, model, trace) = random_slurm_trace(rng);
        let seed = rng.next_u64();
        let mut indexed = SlurmCore::new(cluster.clone(), model.clone(), seed);
        let mut reference =
            ReferenceSlurmCore::new(cluster, model, seed);
        let a = drive_slurm_trace(&mut indexed, &trace);
        let b = drive_slurm_trace(&mut reference, &trace);
        assert_eq!(a, b, "indexed slurm core diverged from seed semantics");
    });
}

/// Uniform driver surface over the indexed and reference HQ cores.
trait HqLike {
    fn submit_task(&mut self, t: Micros, spec: TaskSpec) -> (TaskId, Vec<HqAction>);
    fn on_alloc_up(&mut self, t: Micros, life: Micros, cores: u32) -> Vec<HqAction>;
    fn on_timer(&mut self, t: Micros, tm: HqTimer) -> Vec<HqAction>;
    fn on_task_done(&mut self, t: Micros, id: TaskId) -> Vec<HqAction>;
    fn expire_workers(&mut self, t: Micros) -> Vec<HqAction>;
}

macro_rules! impl_hq_like {
    ($ty:ty) => {
        impl HqLike for $ty {
            fn submit_task(&mut self, t: Micros, spec: TaskSpec)
                           -> (TaskId, Vec<HqAction>) {
                <$ty>::submit_task(self, t, spec)
            }
            fn on_alloc_up(&mut self, t: Micros, life: Micros, cores: u32)
                           -> Vec<HqAction> {
                <$ty>::on_alloc_up(self, t, life, cores)
            }
            fn on_timer(&mut self, t: Micros, tm: HqTimer) -> Vec<HqAction> {
                <$ty>::on_timer(self, t, tm)
            }
            fn on_task_done(&mut self, t: Micros, id: TaskId) -> Vec<HqAction> {
                <$ty>::on_task_done(self, t, id)
            }
            fn expire_workers(&mut self, t: Micros) -> Vec<HqAction> {
                <$ty>::expire_workers(self, t)
            }
        }
    };
}

impl_hq_like!(HqCore);
impl_hq_like!(ReferenceHqCore);

#[derive(Debug, PartialEq, Default)]
struct HqObs {
    starts: Vec<(TaskId, u64)>, // (task, worker)
    kills: Vec<TaskId>,
    allocs: Vec<u64>,           // alloc tags submitted
    records: Vec<(TaskId, JobRecord)>,
}

/// Drive a task trace; allocations come up `alloc_delay` later with
/// lifetime `alloc_life`; periodic `Expire` probes retire due workers.
fn drive_hq_trace<C: HqLike>(
    core: &mut C,
    submissions: &[(Micros, TaskSpec)],
    durations: &[Micros],
    alloc_delay: Micros,
    alloc_life: Micros,
) -> HqObs {
    #[derive(Debug)]
    enum Ev {
        Submit(usize),
        AllocUp,
        Timer(HqTimer),
        TaskDone(TaskId),
        Expire,
    }
    let mut des: Des<Ev> = Des::new();
    for (i, (t, _)) in submissions.iter().enumerate() {
        des.schedule(*t, Ev::Submit(i));
    }
    // Expiry probes throughout the plausible sim horizon (generously past
    // any reachable completion time, so aged-out workers always retire).
    for k in 1..150u64 {
        des.schedule(k * alloc_life / 7 + k * SEC, Ev::Expire);
    }
    let mut obs = HqObs::default();
    let mut durs: HashMap<TaskId, Micros> = HashMap::new();
    let mut records = 0usize;
    let mut guard = 0u64;
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 2_000_000, "runaway hq equivalence trace");
        let acts = match ev {
            Ev::Submit(i) => {
                let (id, acts) =
                    core.submit_task(t, submissions[i].1.clone());
                durs.insert(id, durations[i]);
                acts
            }
            Ev::AllocUp => core.on_alloc_up(t, alloc_life, 16),
            Ev::Timer(tm) => core.on_timer(t, tm),
            Ev::TaskDone(id) => core.on_task_done(t, id),
            Ev::Expire => core.expire_workers(t),
        };
        for a in acts {
            match a {
                HqAction::SubmitAllocation { alloc_tag, .. } => {
                    obs.allocs.push(alloc_tag);
                    des.schedule(t + alloc_delay, Ev::AllocUp);
                }
                HqAction::StartTask { task, worker } => {
                    obs.starts.push((task, worker));
                    let dur = durs[&task];
                    des.schedule(t + dur, Ev::TaskDone(task));
                }
                // Single-worker cores never emit gang starts; a stray
                // one would be an equivalence break, so fail loudly.
                HqAction::StartGang { task, .. } => {
                    panic!("unexpected StartGang for task {task}")
                }
                HqAction::KillTask { task } => obs.kills.push(task),
                HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                HqAction::TaskCompleted { task, record } => {
                    records += 1;
                    obs.records.push((task, record));
                }
                // Worker expiry requeues running tasks; the core
                // re-dispatches them itself, so the trace just observes.
                HqAction::Requeued { .. } => {}
            }
        }
        if records >= submissions.len() {
            break;
        }
    }
    assert_eq!(records, submissions.len(), "hq trace did not complete");
    obs
}

/// Rewrite task and worker ids to admission ranks.  Ascending raw id ==
/// admission order in *both* id schemes (the reference core's sequential
/// counters and the table's generational slab keys, whose sequence lives
/// in the high bits), so ranking over the sorted distinct ids compares
/// the two cores' decisions without depending on the id encoding.
fn normalise_obs(mut obs: HqObs) -> HqObs {
    let mut tasks: Vec<TaskId> = obs
        .starts
        .iter()
        .map(|&(task, _)| task)
        .chain(obs.kills.iter().copied())
        .chain(obs.records.iter().map(|&(task, _)| task))
        .collect();
    tasks.sort_unstable();
    tasks.dedup();
    let mut workers: Vec<u64> =
        obs.starts.iter().map(|&(_, w)| w).collect();
    workers.sort_unstable();
    workers.dedup();
    let trank = |id: TaskId| -> TaskId {
        1 + tasks.binary_search(&id).expect("task seen in stream") as u64
    };
    let wrank = |id: u64| -> u64 {
        1 + workers.binary_search(&id).expect("worker seen in stream") as u64
    };
    for s in &mut obs.starts {
        *s = (trank(s.0), wrank(s.1));
    }
    for k in &mut obs.kills {
        *k = trank(*k);
    }
    for r in &mut obs.records {
        r.0 = trank(r.0);
    }
    obs
}

#[test]
fn prop_indexed_hq_core_equals_reference() {
    prop::check("hq-indexed-equivalence", 16, |rng| {
        let n = 4 + rng.below(28) as usize;
        // Keep (time, spec, duration) together through the sort: task ids
        // are assigned in submission-fire order, and the driver looks
        // durations up by task id.
        let mut subs: Vec<(Micros, TaskSpec, Micros)> = (0..n)
            .map(|i| {
                let t = rng.below(90) * SEC;
                let spec = TaskSpec {
                    tag: i as u64,
                    // Occasionally zero cores: degenerate but seed-legal
                    // (dispatches to any live worker regardless of load).
                    cores: if rng.uniform() < 0.05 {
                        0
                    } else {
                        1 + rng.below(16) as u32
                    },
                    time_request: (1 + rng.below(40)) * SEC,
                    // Occasionally tight: exercises the kill path.
                    time_limit: if rng.uniform() < 0.15 {
                        (1 + rng.below(4)) * SEC
                    } else {
                        1000 * SEC
                    },
                };
                let dur = (1 + rng.below(16)) * SEC / 2;
                (t, spec, dur)
            })
            .collect();
        subs.sort_by_key(|(t, ..)| *t);
        let submissions: Vec<(Micros, TaskSpec)> =
            subs.iter().map(|(t, s, _)| (*t, s.clone())).collect();
        let durations: Vec<Micros> = subs.iter().map(|(.., d)| *d).collect();
        let alloc_delay = (1 + rng.below(20)) * SEC;
        // Long enough that every time_request (<= 41 s) can be served.
        let alloc_life = (60 + rng.below(300)) * SEC;
        let cfg = AutoAllocConfig {
            backlog: 1 + rng.below(3) as u32,
            workers_per_alloc: 1 + rng.below(2) as u32,
            max_worker_count: 2 + rng.below(4) as u32,
            alloc_request: JobRequest::new(16, 16, alloc_life),
            dispatch_latency: 1 * MS,
        };
        let mut indexed = HqCore::new(cfg.clone());
        let mut reference = ReferenceHqCore::new(cfg);
        let a = drive_hq_trace(&mut indexed, &submissions, &durations,
                               alloc_delay, alloc_life);
        let b = drive_hq_trace(&mut reference, &submissions, &durations,
                               alloc_delay, alloc_life);
        assert_eq!(normalise_obs(a), normalise_obs(b),
                   "indexed hq core diverged from seed semantics");
    });
}

/// Regression: cancel-while-pending must remove the exact lane entry
/// (the indexed core's O(log n) deletion) and leave every other pending
/// job schedulable in the original priority order.
#[test]
fn cancel_while_pending_under_indexed_queue() {
    let model = OverheadModel::quiet();
    let mut core = SlurmCore::new(ClusterSpec::small(1), model.clone(), 7);
    let mut reference =
        ReferenceSlurmCore::new(ClusterSpec::small(1), model.clone(), 7);
    let n = 20u64;
    let mut ids = Vec::new();
    for i in 0..n {
        let req = JobRequest::new(1, 1, 1000 * SEC);
        let (a, _) = core.submit(i, USER_EXPERIMENT, i, req);
        let (b, _) = reference.submit(i, USER_EXPERIMENT, i, req);
        assert_eq!(a, b);
        ids.push(a);
    }
    // Make everything pending.
    for &id in &ids {
        let te = model.submit_latency + n;
        core.on_timer(te, Timer::Eligible(id));
        reference.on_timer(te, Timer::Eligible(id));
    }
    assert_eq!(core.pending_count(), n as usize);
    // Cancel a mid-queue slice.
    for &id in &ids[5..10] {
        let acts_a = core.cancel(2 * SEC, id);
        let acts_b = reference.cancel(2 * SEC, id);
        assert_eq!(acts_a.len(), 1);
        assert!(matches!(&acts_a[0],
                         Action::Completed { record, .. } if record.truncated));
        assert_eq!(format!("{acts_a:?}"), format!("{acts_b:?}"));
    }
    assert_eq!(core.pending_count(), 15);
    assert_eq!(core.pending_count(), reference.pending_count());
    // One cycle on the 16-core node: all 15 surviving jobs start, the
    // cancelled ones never do, and both cores start the same set.
    let acts_a = core.on_timer(30 * SEC, Timer::Cycle);
    let acts_b = reference.on_timer(30 * SEC, Timer::Cycle);
    let starts = |acts: &[Action]| -> Vec<JobId> {
        acts.iter()
            .filter_map(|a| match a {
                Action::Timer(_, Timer::Start(id)) => Some(*id),
                _ => None,
            })
            .collect()
    };
    let sa = starts(&acts_a);
    let sb = starts(&acts_b);
    assert_eq!(sa, sb);
    assert_eq!(sa.len(), 15);
    for &id in &ids[5..10] {
        assert!(!sa.contains(&id), "cancelled job {id} started");
        assert_eq!(core.state_of(id),
                   Some(uqsched::slurmlite::JobState::Cancelled));
    }
}

// ---------------------------------------------------------------------------
// Pluggability: all five schedulers through ONE generic harness.
//
// The `SchedulerCore` seam promises that a campaign is scheduler-
// agnostic: the same protocol, driven by the same generic kernel, must
// satisfy the same structural properties on every implementation —
// SLURM, the HQ stack, the work-stealing stack, the EDF stack, and the
// moldable-gang stack.
// ---------------------------------------------------------------------------

/// The paper's fixed-depth protocol through the generic kernel, against
/// any scheduler — the whole point of the trait.
fn run_generic<S: SchedulerCore>(core: &mut S, cfg: &Config) -> CampaignResult {
    let mut sub =
        FixedDepth::new(cfg.app, cfg.n_evals, cfg.queue_depth, cfg.seed);
    kernel::run(core, &mut sub)
}

#[test]
fn prop_all_five_cores_through_one_scheduler_core_harness() {
    prop::check("sched-core-generic", 8, |rng| {
        let cfg = random_cfg(rng);
        let ccfg = cfg.campaign();
        let mut results: Vec<CampaignResult> = Vec::new();
        {
            let mut core = SlurmSched::new(&ccfg, SlurmMode::Native);
            results.push(run_generic(&mut core, &cfg));
        }
        {
            let mut core =
                MetaStack::new(&ccfg, HqCore::new(ccfg.autoalloc()), "HQ");
            results.push(run_generic(&mut core, &cfg));
        }
        {
            let mut core = MetaStack::new(
                &ccfg,
                WorkStealCore::new(ccfg.autoalloc()),
                "worksteal",
            );
            results.push(run_generic(&mut core, &cfg));
        }
        {
            let mut core = MetaStack::new(
                &ccfg,
                EdfCore::new(ccfg.autoalloc()),
                "edf",
            );
            results.push(run_generic(&mut core, &cfg));
        }
        {
            let mut core = MetaStack::new(
                &ccfg,
                GangCore::new(ccfg.autoalloc()).with_gang(1, 2),
                "gang",
            );
            results.push(run_generic(&mut core, &cfg));
        }
        for r in &results {
            let label = &r.metrics.scheduler;
            assert_eq!(r.experiment.records.len() as u64, cfg.n_evals,
                       "{label}: wrong record count");
            assert_eq!(r.metrics.completed, cfg.n_evals,
                       "{label}: wrong completion count");
            assert_eq!(r.metrics.submitted, cfg.n_evals,
                       "{label}: fixed-depth submits exactly n");
            let mut tags: Vec<u64> =
                r.experiment.records.iter().map(|x| x.tag).collect();
            tags.sort_unstable();
            tags.dedup();
            assert_eq!(tags.len() as u64, cfg.n_evals,
                       "{label}: duplicated/lost tags");
            for rec in &r.experiment.records {
                assert!(rec.submit <= rec.start && rec.start <= rec.end,
                        "{label}: time ordering violated");
            }
        }
    });
}

#[test]
fn prop_worksteal_campaign_deterministic_under_seed() {
    prop::check("worksteal-determinism", 4, |rng| {
        let cfg = random_cfg(rng);
        let run = || {
            let ccfg = cfg.campaign();
            let mut core = MetaStack::new(
                &ccfg,
                WorkStealCore::new(ccfg.autoalloc()),
                "worksteal",
            );
            run_generic(&mut core, &cfg)
        };
        let a = run();
        let b = run();
        assert_eq!(a.experiment.records.len(), b.experiment.records.len());
        for (x, y) in a.experiment.records.iter().zip(&b.experiment.records) {
            assert_eq!(x, y, "worksteal campaign not seed-deterministic");
        }
    });
}

/// Worker loss injected through the `SchedulerCore` capacity-change
/// seam itself (`MetaStack::on_capacity_change_into`): the full
/// UM-Bridge + worksteal stack must requeue and finish every
/// evaluation.  Drives the stack through its trait surface with a
/// miniature kernel so a capacity event can be injected mid-flight
/// (the production kernel never emits one on the paper paths).
#[test]
fn stack_capacity_change_requeues_without_loss() {
    let mut ccfg = CampaignConfig::paper(App::Gp, 2, 9);
    ccfg.cluster = ClusterSpec::small(8);
    ccfg.overheads.bg_interarrival = Micros::MAX;
    ccfg.registration_jobs = 0;
    let mut core = MetaStack::new(
        &ccfg,
        WorkStealCore::new(ccfg.autoalloc()),
        "worksteal",
    );

    #[derive(Debug)]
    enum Ev {
        Timer(StackTimer),
        WorkDone(TaskId),
        Lose(u64),
    }
    let n = 6u64;
    let mut des: Des<Ev> = Des::new();
    let mut effects = Vec::new();
    let mut durs: HashMap<TaskId, uqsched::clock::Micros> = HashMap::new();
    core.bootstrap_into(0, &mut effects);
    for tag in 0..n {
        let s = Submission { tag, user: 0, app: App::Gp, duration: 2 * SEC };
        let (tid, dur) = core.submit_into(0, &s, &mut effects);
        durs.insert(tid, dur);
    }

    let mut now: Micros = 0;
    let mut lost_injected = false;
    let mut tags: Vec<u64> = Vec::new();
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 100_000, "runaway capacity-change trace");
        for e in effects.drain(..) {
            match e {
                Effect::SetTimer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                Effect::Start { id, contention, .. } => {
                    if !lost_injected {
                        // Yank the first worker the moment it takes work.
                        lost_injected = true;
                        des.schedule(now, Ev::Lose(0));
                    }
                    let dd = (durs[&id] as f64 * contention) as Micros;
                    des.schedule(now + dd, Ev::WorkDone(id));
                }
                Effect::Finish { record, .. } => {
                    assert_ne!(record.tag, u64::MAX);
                    tags.push(record.tag);
                }
                Effect::Retire { .. }
                | Effect::Queued
                | Effect::Released { .. } => {}
            }
        }
        if tags.len() as u64 >= n {
            break;
        }
        let Some((t, ev)) = des.pop() else { break };
        now = t;
        match ev {
            Ev::Timer(tm) => core.on_timer_into(t, tm, &mut effects),
            Ev::WorkDone(id) => core.on_work_done_into(t, id, &mut effects),
            Ev::Lose(_) => {
                // Resolve the victim at fire time: the lowest live
                // worker id is the earliest-admitted worker.
                let mut live = Vec::new();
                core.live_worker_ids(&mut live);
                live.sort_unstable();
                let wid = *live.first().expect("a worker is live");
                core.on_capacity_change_into(
                    t,
                    CapacityChange::WorkerLost(wid),
                    &mut effects,
                );
            }
        }
    }
    assert!(lost_injected, "a worker must have taken work");
    tags.sort_unstable();
    tags.dedup();
    assert_eq!(tags.len() as u64, n,
               "capacity change through the seam lost evaluations");
    assert_eq!(core.meta().retired_count(), n);
    assert_eq!(core.meta().resident_tasks(), 0);
}

// ---------------------------------------------------------------------------
// Work-stealing invariants under worker churn: random task streams with
// workers yanked away mid-flight.  No task may be lost (every
// submission produces exactly one terminal record) and every private
// deque stays FIFO (ascending task id) at all times — owners pop the
// front, thieves the back.
// ---------------------------------------------------------------------------

#[test]
fn prop_worksteal_no_task_lost_and_deques_fifo_under_churn() {
    prop::check("worksteal-churn", 10, |rng| {
        let n = 5 + rng.below(20) as usize;
        let cfg = AutoAllocConfig {
            backlog: 1 + rng.below(3) as u32,
            workers_per_alloc: 1 + rng.below(2) as u32,
            max_worker_count: 2 + rng.below(4) as u32,
            alloc_request: JobRequest::new(16, 16, 1000 * SEC),
            dispatch_latency: 1 * MS,
        };
        let specs: Vec<(Micros, TaskSpec, Micros)> = (0..n)
            .map(|i| {
                let t = rng.below(60) * SEC;
                let spec = TaskSpec {
                    tag: i as u64,
                    cores: 1 + rng.below(16) as u32,
                    time_request: (1 + rng.below(20)) * SEC,
                    time_limit: 1000 * SEC,
                };
                let dur = (1 + rng.below(12)) * SEC / 2;
                (t, spec, dur)
            })
            .collect();

        #[derive(Debug)]
        enum Ev {
            Submit(usize),
            AllocUp,
            Timer(HqTimer),
            Done(TaskId),
            Lose(u64),
        }
        let mut des: Des<Ev> = Des::new();
        for (i, (t, ..)) in specs.iter().enumerate() {
            des.schedule(*t, Ev::Submit(i));
        }
        // Worker churn: a few losses at random times against random
        // (possibly never-existing) worker ids — misses must be no-ops.
        for _ in 0..(1 + rng.below(4)) {
            des.schedule((5 + rng.below(120)) * SEC,
                         Ev::Lose(1 + rng.below(8)));
        }
        let alloc_delay = (1 + rng.below(10)) * SEC;

        let mut core = WorkStealCore::new(cfg);
        // Durations by the task id the core assigned at submit time.
        let mut durs: HashMap<TaskId, Micros> = HashMap::new();
        // Every worker ever admitted, in admission order: churn picks a
        // victim from here (already-lost entries exercise the stale-id
        // no-op path).
        let mut admitted: Vec<u64> = Vec::new();
        let mut records: Vec<JobRecord> = Vec::new();
        let mut acts: Vec<HqAction> = Vec::new();
        let mut guard = 0u64;
        while let Some((t, ev)) = des.pop() {
            guard += 1;
            assert!(guard < 500_000, "runaway churn trace");
            acts.clear();
            match ev {
                Ev::Submit(i) => {
                    let (_, spec, dur) = &specs[i];
                    let id = core.submit_task_into(t, spec.clone(),
                                                   &mut acts);
                    durs.insert(id, *dur);
                }
                Ev::AllocUp => {
                    if let Some(w) =
                        core.on_alloc_up_into(t, 1000 * SEC, 16, &mut acts)
                    {
                        admitted.push(w);
                    }
                }
                Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
                Ev::Done(id) => core.on_task_done_into(t, id, &mut acts),
                Ev::Lose(r) => {
                    // Pick a victim among ever-admitted workers; with
                    // none yet, the raw draw is a guaranteed miss and
                    // must be a no-op.
                    let wid = admitted
                        .get(r as usize % admitted.len().max(1))
                        .copied()
                        .unwrap_or(r);
                    core.on_worker_lost_into(t, wid, &mut acts);
                }
            }
            assert!(core.deques_fifo(),
                    "a steal or requeue broke per-deque FIFO order");
            for a in acts.drain(..) {
                match a {
                    HqAction::SubmitAllocation { .. } => {
                        des.schedule(t + alloc_delay, Ev::AllocUp);
                    }
                    HqAction::StartTask { task, .. }
                    | HqAction::StartGang { task, .. } => {
                        let dur = durs[&task];
                        des.schedule(t + dur, Ev::Done(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record);
                    }
                    HqAction::KillTask { .. } => {}
                    HqAction::Requeued { .. } => {}
                }
            }
            if records.len() >= n {
                break;
            }
        }
        assert_eq!(records.len(), n,
                   "worker churn lost tasks: {} of {n} completed",
                   records.len());
        let mut tags: Vec<u64> = records.iter().map(|r| r.tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), n, "duplicate/lost completions under churn");
        assert_eq!(core.resident_tasks(), 0, "hot map drained");
    });
}

// ---------------------------------------------------------------------------
// Deadline-EDF invariants: strict earliest-deadline-first pop order,
// no starvation under sustained short-deadline load, seed determinism.
// ---------------------------------------------------------------------------

/// Drive a bare `EdfCore` through a DES: submissions at given times,
/// allocations come up `alloc_delay` after request, tasks run `dur`.
/// Returns `(start_order, records, submitted_ids)` — the last in
/// submission-fire order, so callers can translate spec indices to the
/// core's assigned ids.
fn drive_edf(
    core: &mut EdfCore,
    submissions: &[(Micros, TaskSpec)],
    alloc_delay: Micros,
    dur: Micros,
) -> (Vec<TaskId>, Vec<JobRecord>, Vec<TaskId>) {
    #[derive(Debug)]
    enum Ev {
        Submit(usize),
        AllocUp,
        Timer(HqTimer),
        Done(TaskId),
    }
    let mut des: Des<Ev> = Des::new();
    for (i, (t, _)) in submissions.iter().enumerate() {
        des.schedule(*t, Ev::Submit(i));
    }
    let mut starts = Vec::new();
    let mut records = Vec::new();
    let mut submitted = Vec::new();
    let mut acts: Vec<HqAction> = Vec::new();
    let mut guard = 0u64;
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 500_000, "runaway edf trace");
        acts.clear();
        match ev {
            Ev::Submit(i) => {
                submitted.push(core.submit_task_into(
                    t,
                    submissions[i].1.clone(),
                    &mut acts,
                ));
            }
            Ev::AllocUp => {
                let _ = core.on_alloc_up_into(t, 100_000 * SEC, 16, &mut acts);
            }
            Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
            Ev::Done(id) => core.on_task_done_into(t, id, &mut acts),
        }
        for a in acts.drain(..) {
            match a {
                HqAction::SubmitAllocation { .. } => {
                    des.schedule(t + alloc_delay, Ev::AllocUp);
                }
                HqAction::StartTask { task, .. }
                | HqAction::StartGang { task, .. } => {
                    starts.push(task);
                    des.schedule(t + dur, Ev::Done(task));
                }
                HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                HqAction::TaskCompleted { record, .. } => {
                    records.push(record);
                }
                HqAction::KillTask { .. } => {}
                HqAction::Requeued { .. } => {}
            }
        }
        if records.len() >= submissions.len() {
            break;
        }
    }
    assert_eq!(records.len(), submissions.len(), "edf trace incomplete");
    (starts, records, submitted)
}

#[test]
fn prop_edf_pops_in_deadline_laxity_id_order() {
    prop::check("edf-pop-order", 10, |rng| {
        // One serial worker (16-core tasks), everything submitted at
        // t=0: the observed start order must equal the (deadline,
        // laxity, id) sort — EDF's defining property.
        let n = 4 + rng.below(12) as usize;
        let specs: Vec<(Micros, TaskSpec)> = (0..n)
            .map(|i| {
                (0, TaskSpec {
                    tag: i as u64,
                    cores: 16,
                    time_request: (1 + rng.below(10)) * SEC,
                    time_limit: (30 + rng.below(500)) * SEC,
                })
            })
            .collect();
        let mut core = EdfCore::new(AutoAllocConfig {
            backlog: 1,
            workers_per_alloc: 1,
            max_worker_count: 1,
            alloc_request: JobRequest::new(16, 16, 100_000 * SEC),
            dispatch_latency: 1 * MS,
        });
        let (starts, _, submitted) = drive_edf(&mut core, &specs, SEC, 2 * SEC);
        assert_eq!(starts.len(), n);
        // All submissions fire at t=0 in spec order, so submitted[i] is
        // spec i's core-assigned id (ascending — admission order).
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| {
            let s = &specs[i].1;
            (s.time_limit, s.time_limit - s.time_request, submitted[i])
        });
        let expect: Vec<TaskId> =
            order.iter().map(|&i| submitted[i]).collect();
        assert_eq!(starts, expect,
                   "EDF start order must follow (deadline, laxity, id)");
    });
}

#[test]
fn edf_no_starvation_under_sustained_short_deadline_load() {
    // A long-deadline task arrives first; short-deadline tasks arrive
    // exactly as fast as the worker serves them, so while the long task
    // waits there is *always* a fresher, earlier-deadline competitor.
    // Absolute deadlines still guarantee it runs: once newcomers'
    // `now + 30 s` passes its fixed `120 s` deadline (~t = 90 s) the old
    // task is the earliest deadline in the queue.
    let long_limit = 120 * SEC;
    let mut specs: Vec<(Micros, TaskSpec)> = vec![(0, TaskSpec {
        tag: 0,
        cores: 16,
        time_request: SEC,
        time_limit: long_limit,
    })];
    // Shorts every 2 s for 400 s, each running 2 s: utilization 1 while
    // the long task is pending (the worker never idles around it).
    let n_short = 200u64;
    for i in 0..n_short {
        specs.push((2 * i * SEC, TaskSpec {
            tag: 1 + i,
            cores: 16,
            time_request: SEC,
            time_limit: 30 * SEC,
        }));
    }
    let mut core = EdfCore::new(AutoAllocConfig {
        backlog: 1,
        workers_per_alloc: 1,
        max_worker_count: 1,
        alloc_request: JobRequest::new(16, 16, 100_000 * SEC),
        dispatch_latency: 1 * MS,
    });
    let (_starts, records, _submitted) =
        drive_edf(&mut core, &specs, SEC, 2 * SEC);
    let long = records.iter().find(|r| r.tag == 0).expect("long task ran");
    assert!(!long.truncated, "long task must complete, not be killed");
    // Pressure was real: ~45 earlier-deadline shorts ran first…
    assert!(long.start >= 80 * SEC,
            "expected sustained contention before the long task, \
             started at {}", long.start);
    // …but it was never starved past its own deadline window.
    assert!(long.start <= long_limit,
            "starved: long task started at {} (deadline {})",
            long.start, long_limit);
    // Nothing else starved either: every submission completed.
    assert_eq!(records.len() as u64, 1 + n_short);
}

#[test]
fn prop_edf_campaign_deterministic_under_seed() {
    prop::check("edf-determinism", 4, |rng| {
        let cfg = random_cfg(rng);
        let run = || {
            let ccfg = cfg.campaign();
            let mut core = MetaStack::new(
                &ccfg,
                EdfCore::new(ccfg.autoalloc()),
                "edf",
            );
            run_generic(&mut core, &cfg)
        };
        let a = run();
        let b = run();
        assert_eq!(a.experiment.records.len(), b.experiment.records.len());
        for (x, y) in a.experiment.records.iter().zip(&b.experiment.records) {
            assert_eq!(x, y, "edf campaign not seed-deterministic");
        }
    });
}

// ---------------------------------------------------------------------------
// Chaos properties: seeded fault plans through the generic kernel.
//
// The plan is a pure function of (seed, tag) — see faults.rs — so all
// five cores must exhibit the *same* failure trace: the same per-tag
// retry totals and the exact same quarantine set, however differently
// they order the work.  No task may be lost or double-completed, and a
// quarantined task must still surface as a (truncated) record.
// ---------------------------------------------------------------------------

/// Chaos configs stick to the fast apps (durations of seconds against
/// minutes-scale time limits) so retry accumulation can never trip a
/// wall-clock limit — truncation then has exactly one cause
/// (quarantine), which the assertions below rely on.
fn chaos_cfg(rng: &mut Rng) -> Config {
    let app = if rng.uniform() < 0.5 { App::Eigen100 } else { App::Gp };
    let qd = [1usize, 2, 3][rng.below(3) as usize];
    let mut cfg = Config::paper(app, qd, rng.next_u64());
    cfg.n_evals = 6 + rng.below(10);
    cfg.cluster = ClusterSpec::small(4 + rng.below(4) as usize);
    cfg.overheads.bg_interarrival = Micros::MAX;
    cfg
}

fn chaos_sub(cfg: &Config) -> FixedDepth {
    FixedDepth::new(cfg.app, cfg.n_evals, cfg.queue_depth, cfg.seed)
}

/// Everything failure-observable about a run: (retries, quarantined,
/// sorted quarantined tags).
fn fail_sig(r: &CampaignResult) -> (u64, u64, Vec<u64>) {
    let mut q: Vec<u64> = r
        .experiment
        .records
        .iter()
        .filter(|x| x.truncated)
        .map(|x| x.tag)
        .collect();
    q.sort_unstable();
    (r.metrics.retries, r.metrics.quarantined, q)
}

fn assert_chaos_invariants(r: &CampaignResult, cfg: &Config, plan: &FaultPlan) {
    let label = &r.metrics.scheduler;
    assert_eq!(r.experiment.records.len() as u64, cfg.n_evals,
               "{label}: lost records under faults");
    assert_eq!(r.metrics.completed, cfg.n_evals,
               "{label}: wrong completion count under faults");
    let mut tags: Vec<u64> =
        r.experiment.records.iter().map(|x| x.tag).collect();
    tags.sort_unstable();
    tags.dedup();
    assert_eq!(tags.len() as u64, cfg.n_evals,
               "{label}: duplicated/lost tags under faults");
    // Truncation has exactly one cause here: budget exhaustion, which
    // the plan predicts per tag independently of the core.
    for rec in &r.experiment.records {
        assert_eq!(rec.truncated, plan.quarantines(rec.tag),
                   "{label}: tag {} truncated={} but plan.quarantines={}",
                   rec.tag, rec.truncated, plan.quarantines(rec.tag));
    }
    let q = r.experiment.records.iter().filter(|x| x.truncated).count();
    assert_eq!(r.metrics.quarantined, q as u64,
               "{label}: quarantine counter disagrees with records");
}

#[test]
fn prop_chaos_identical_failure_traces_across_all_five_cores() {
    prop::check("chaos-cross-core", 6, |rng| {
        let cfg = chaos_cfg(rng);
        let spec = FaultSpec {
            seed: rng.next_u64(),
            task_fail_p: 0.15 + rng.uniform() * 0.25,
            max_attempts: 2 + rng.below(3) as u32, // 2..=4
            backoff_base: 500 * MS,
            backoff_cap: 2 * SEC,
            ..FaultSpec::default()
        };
        let plan = FaultPlan::new(spec.clone());
        let mut ccfg = cfg.campaign();
        ccfg.faults = Some(spec);
        let results = [
            run_slurm(&ccfg, &mut chaos_sub(&cfg), SlurmMode::Native),
            run_hq(&ccfg, &mut chaos_sub(&cfg)),
            run_worksteal(&ccfg, &mut chaos_sub(&cfg)),
            run_edf(&ccfg, &mut chaos_sub(&cfg)),
            run_gang(&ccfg, &mut chaos_sub(&cfg)),
        ];
        for r in &results {
            assert_chaos_invariants(r, &cfg, &plan);
            assert_eq!(r.metrics.worker_crashes, 0);
        }
        // The headline: one plan, one seed, one failure trace — on
        // every scheduler.  (No crashes here, so even the retry totals
        // must agree; crash-driven requeues are core-dependent.)
        let sig0 = fail_sig(&results[0]);
        for r in &results[1..] {
            assert_eq!(fail_sig(r), sig0,
                       "{}: failure trace diverged from {}",
                       r.metrics.scheduler, results[0].metrics.scheduler);
        }
    });
}

#[test]
fn prop_chaos_crashes_never_lose_tasks_and_quarantine_is_crash_immune() {
    prop::check("chaos-crash", 5, |rng| {
        let cfg = chaos_cfg(rng);
        let spec = FaultSpec {
            seed: rng.next_u64(),
            crash_every: (20 + rng.below(40)) * SEC,
            task_fail_p: 0.1,
            max_attempts: 3,
            backoff_base: 500 * MS,
            backoff_cap: 2 * SEC,
            ..FaultSpec::default()
        };
        let plan = FaultPlan::new(spec.clone());
        let mut ccfg = cfg.campaign();
        ccfg.faults = Some(spec);
        let results = [
            run_slurm(&ccfg, &mut chaos_sub(&cfg), SlurmMode::Native),
            run_hq(&ccfg, &mut chaos_sub(&cfg)),
            run_worksteal(&ccfg, &mut chaos_sub(&cfg)),
            run_edf(&ccfg, &mut chaos_sub(&cfg)),
            run_gang(&ccfg, &mut chaos_sub(&cfg)),
        ];
        // Crash interactions may reorder work and force extra (free)
        // requeues, but the failure *fate* is keyed on accepted failures
        // — so the quarantine set is identical across cores even though
        // each core loses different workers at different moments.
        for r in &results {
            assert_chaos_invariants(r, &cfg, &plan);
        }
    });
}

#[test]
fn prop_chaos_runs_are_seed_deterministic_and_zero_plan_is_noop() {
    prop::check("chaos-determinism", 4, |rng| {
        let cfg = chaos_cfg(rng);
        // A plan that injects nothing must be byte-equivalent to no
        // plan at all: same records, same order, same timings.
        let clean = cfg.campaign();
        let mut zero = cfg.campaign();
        zero.faults = Some(FaultSpec {
            seed: rng.next_u64(),
            ..FaultSpec::default()
        });
        let a = run_hq(&clean, &mut chaos_sub(&cfg));
        let b = run_hq(&zero, &mut chaos_sub(&cfg));
        assert_eq!(a.experiment.records, b.experiment.records,
                   "a zero fault plan changed the schedule");
        // And a genuinely chaotic run replays bit-for-bit on its seed.
        let mut chaos = cfg.campaign();
        chaos.faults = Some(FaultSpec {
            seed: rng.next_u64(),
            crash_every: 30 * SEC,
            task_fail_p: 0.2,
            max_attempts: 3,
            backoff_base: 500 * MS,
            backoff_cap: 2 * SEC,
            ..FaultSpec::default()
        });
        let c = run_worksteal(&chaos, &mut chaos_sub(&cfg));
        let d = run_worksteal(&chaos, &mut chaos_sub(&cfg));
        assert_eq!(c.experiment.records, d.experiment.records,
                   "chaotic run not seed-deterministic");
        assert_eq!(fail_sig(&c), fail_sig(&d));
        assert_eq!(c.metrics.worker_crashes, d.metrics.worker_crashes);
    });
}

// ---------------------------------------------------------------------------
// Gang invariants under worker churn: moldable-width submissions with
// workers yanked away mid-flight.  The all-slots-or-none invariant
// (`no_partial_gangs`) must hold after *every* event — losing one gang
// member releases every other member's slots in the same transition —
// and no task may be lost.
// ---------------------------------------------------------------------------

#[test]
fn prop_gang_no_partial_gangs_under_churn() {
    prop::check("gang-churn", 10, |rng| {
        let n = 5 + rng.below(20) as usize;
        let cfg = AutoAllocConfig {
            backlog: 1 + rng.below(3) as u32,
            workers_per_alloc: 1 + rng.below(2) as u32,
            max_worker_count: 2 + rng.below(4) as u32,
            alloc_request: JobRequest::new(16, 16, 1000 * SEC),
            dispatch_latency: 1 * MS,
        };
        // (submit time, spec, duration, min width, max width): moldable
        // bounds are random but always satisfiable by the worker cap.
        let specs: Vec<(Micros, TaskSpec, Micros, u32, u32)> = (0..n)
            .map(|i| {
                let t = rng.below(60) * SEC;
                let spec = TaskSpec {
                    tag: i as u64,
                    cores: 1 + rng.below(16) as u32,
                    time_request: (1 + rng.below(20)) * SEC,
                    time_limit: 1000 * SEC,
                };
                let dur = (1 + rng.below(12)) * SEC / 2;
                let min = 1 + rng.below(2) as u32; // 1..=2 <= worker cap
                let max = min + rng.below(3) as u32;
                (t, spec, dur, min, max)
            })
            .collect();

        #[derive(Debug)]
        enum Ev {
            Submit(usize),
            AllocUp,
            Timer(HqTimer),
            Done(TaskId),
            Lose(u64),
        }
        let mut des: Des<Ev> = Des::new();
        for (i, (t, ..)) in specs.iter().enumerate() {
            des.schedule(*t, Ev::Submit(i));
        }
        // Worker churn against random (possibly never-existing) worker
        // ids — losing a gang member must take the whole gang down
        // cleanly; misses must be no-ops.
        for _ in 0..(1 + rng.below(4)) {
            des.schedule((5 + rng.below(120)) * SEC,
                         Ev::Lose(1 + rng.below(8)));
        }
        let alloc_delay = (1 + rng.below(10)) * SEC;

        let mut core = GangCore::new(cfg);
        // Durations and widths by the task id the core assigned at
        // submit time; churn victims come from the ever-admitted worker
        // list (already-lost entries exercise the stale-id no-op path).
        let mut durs: HashMap<TaskId, Micros> = HashMap::new();
        let mut widths: HashMap<TaskId, (u32, u32)> = HashMap::new();
        let mut admitted: Vec<u64> = Vec::new();
        let mut records: Vec<JobRecord> = Vec::new();
        let mut gang_starts = 0usize;
        let mut acts: Vec<HqAction> = Vec::new();
        let mut guard = 0u64;
        while let Some((t, ev)) = des.pop() {
            guard += 1;
            assert!(guard < 500_000, "runaway gang churn trace");
            acts.clear();
            let ev_dbg = format!("{ev:?}");
            match ev {
                Ev::Submit(i) => {
                    let (_, spec, dur, min, max) = &specs[i];
                    let id = core.submit_gang_task_into(
                        t, spec.clone(), *min, *max, &mut acts,
                    );
                    durs.insert(id, *dur);
                    widths.insert(id, (*min, *max));
                }
                Ev::AllocUp => {
                    if let Some(w) =
                        core.on_alloc_up_into(t, 1000 * SEC, 16, &mut acts)
                    {
                        admitted.push(w);
                    }
                }
                Ev::Timer(tm) => core.on_timer_into(t, tm, &mut acts),
                Ev::Done(id) => core.on_task_done_into(t, id, &mut acts),
                Ev::Lose(r) => {
                    let wid = admitted
                        .get(r as usize % admitted.len().max(1))
                        .copied()
                        .unwrap_or(r);
                    core.on_worker_lost_into(t, wid, &mut acts);
                }
            }
            assert!(core.no_partial_gangs(),
                    "partial gang observable after {ev_dbg} at t={t}");
            for a in acts.drain(..) {
                match a {
                    HqAction::SubmitAllocation { .. } => {
                        des.schedule(t + alloc_delay, Ev::AllocUp);
                    }
                    HqAction::StartTask { task, .. } => {
                        let dur = durs[&task];
                        des.schedule(t + dur, Ev::Done(task));
                    }
                    HqAction::StartGang { task, ref workers } => {
                        // A started gang is within bounds and every
                        // member is distinct.
                        gang_starts += 1;
                        let (min, max) = widths[&task];
                        assert!((workers.len() as u32) >= min.max(2)
                                && (workers.len() as u32) <= max,
                                "gang width {} outside {min}..={max}",
                                workers.len());
                        let mut uniq = workers.clone();
                        uniq.sort_unstable();
                        uniq.dedup();
                        assert_eq!(uniq.len(), workers.len(),
                                   "duplicate members in gang {workers:?}");
                        let dur = durs[&task];
                        des.schedule(t + dur, Ev::Done(task));
                    }
                    HqAction::Timer(tt, tm) => des.schedule(tt, Ev::Timer(tm)),
                    HqAction::TaskCompleted { record, .. } => {
                        records.push(record);
                    }
                    HqAction::KillTask { .. } => {}
                    HqAction::Requeued { .. } => {}
                }
            }
            if records.len() >= n {
                break;
            }
        }
        assert_eq!(records.len(), n,
                   "worker churn lost gang tasks: {} of {n} completed",
                   records.len());
        let mut tags: Vec<u64> = records.iter().map(|r| r.tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), n, "duplicate/lost completions under churn");
        assert_eq!(core.resident_tasks(), 0, "hot map drained");
        // Multi-worker gangs start with probability ~1/2 per task; on a
        // busy trace at least one dispatch (gang or solo) must happen.
        assert!(gang_starts + records.len() > 0);
    });
}

// ---------------------------------------------------------------------------
// Byte-equality pin: the TaskTable-backed HqCore must emit the *exact*
// action stream — same variants, same payloads, same order, same
// timestamps — as the frozen `hqlite::reference` core on identical
// traces.  Stronger than the observational `HqObs` equivalence above:
// nothing is projected out before comparison.
// ---------------------------------------------------------------------------

/// Drive a task trace exactly like [`drive_hq_trace`], but record every
/// emitted action verbatim with its timestamp, then render the stream
/// with task/worker ids rewritten to admission ranks (sorted distinct
/// ids — ascending id == admission order in both id schemes), so the
/// byte pin compares variants, payloads, order and timestamps without
/// depending on the id encoding.
fn collect_hq_action_stream<C: HqLike>(
    core: &mut C,
    submissions: &[(Micros, TaskSpec)],
    durations: &[Micros],
    alloc_delay: Micros,
    alloc_life: Micros,
) -> Vec<String> {
    #[derive(Debug)]
    enum Ev {
        Submit(usize),
        AllocUp,
        Timer(HqTimer),
        TaskDone(TaskId),
        Expire,
    }
    let mut des: Des<Ev> = Des::new();
    for (i, (t, _)) in submissions.iter().enumerate() {
        des.schedule(*t, Ev::Submit(i));
    }
    for k in 1..150u64 {
        des.schedule(k * alloc_life / 7 + k * SEC, Ev::Expire);
    }
    let mut raw: Vec<(Micros, HqAction)> = Vec::new();
    let mut durs: HashMap<TaskId, Micros> = HashMap::new();
    let mut records = 0usize;
    let mut guard = 0u64;
    while let Some((t, ev)) = des.pop() {
        guard += 1;
        assert!(guard < 2_000_000, "runaway hq action-stream trace");
        let acts = match ev {
            Ev::Submit(i) => {
                let (id, acts) =
                    core.submit_task(t, submissions[i].1.clone());
                durs.insert(id, durations[i]);
                acts
            }
            Ev::AllocUp => core.on_alloc_up(t, alloc_life, 16),
            Ev::Timer(tm) => core.on_timer(t, tm),
            Ev::TaskDone(id) => core.on_task_done(t, id),
            Ev::Expire => core.expire_workers(t),
        };
        for a in acts {
            match &a {
                HqAction::SubmitAllocation { .. } => {
                    des.schedule(t + alloc_delay, Ev::AllocUp);
                }
                HqAction::StartTask { task, .. } => {
                    let dur = durs[task];
                    des.schedule(t + dur, Ev::TaskDone(*task));
                }
                HqAction::StartGang { task, .. } => {
                    panic!("unexpected StartGang for task {task}")
                }
                HqAction::Timer(tt, tm) => {
                    des.schedule(*tt, Ev::Timer(*tm));
                }
                HqAction::TaskCompleted { .. } => records += 1,
                HqAction::KillTask { .. } | HqAction::Requeued { .. } => {}
            }
            raw.push((t, a));
        }
        if records >= submissions.len() {
            break;
        }
    }
    assert_eq!(records, submissions.len(), "hq action stream incomplete");

    // Second pass: rank ids, render canonically.
    let mut tasks: Vec<TaskId> = Vec::new();
    let mut workers: Vec<u64> = Vec::new();
    for (_, a) in &raw {
        match a {
            HqAction::SubmitAllocation { .. } => {}
            HqAction::StartTask { task, worker } => {
                tasks.push(*task);
                workers.push(*worker);
            }
            HqAction::StartGang { task, workers: ws } => {
                tasks.push(*task);
                workers.extend_from_slice(ws);
            }
            HqAction::KillTask { task }
            | HqAction::Requeued { task }
            | HqAction::TaskCompleted { task, .. } => tasks.push(*task),
            HqAction::Timer(_, tm) => match tm {
                HqTimer::Dispatched(id)
                | HqTimer::Limit(id)
                | HqTimer::Retry(id) => tasks.push(*id),
            },
        }
    }
    tasks.sort_unstable();
    tasks.dedup();
    workers.sort_unstable();
    workers.dedup();
    let trank = |id: &TaskId| -> u64 {
        1 + tasks.binary_search(id).expect("task seen") as u64
    };
    let wrank = |id: &u64| -> u64 {
        1 + workers.binary_search(id).expect("worker seen") as u64
    };
    raw.iter()
        .map(|(t, a)| match a {
            HqAction::SubmitAllocation { alloc_tag, req } => {
                format!("t={t} SubmitAllocation alloc_tag={alloc_tag} \
                         req={req:?}")
            }
            HqAction::StartTask { task, worker } => {
                format!("t={t} StartTask task={} worker={}",
                        trank(task), wrank(worker))
            }
            HqAction::StartGang { task, workers: ws } => {
                let m: Vec<u64> = ws.iter().map(&wrank).collect();
                format!("t={t} StartGang task={} workers={m:?}", trank(task))
            }
            HqAction::KillTask { task } => {
                format!("t={t} KillTask task={}", trank(task))
            }
            HqAction::Requeued { task } => {
                format!("t={t} Requeued task={}", trank(task))
            }
            HqAction::TaskCompleted { task, record } => {
                format!("t={t} TaskCompleted task={} record={record:?}",
                        trank(task))
            }
            HqAction::Timer(tt, tm) => {
                let p = match tm {
                    HqTimer::Dispatched(id) => {
                        format!("Dispatched({})", trank(id))
                    }
                    HqTimer::Limit(id) => format!("Limit({})", trank(id)),
                    HqTimer::Retry(id) => format!("Retry({})", trank(id)),
                };
                format!("t={t} Timer at={tt} {p}")
            }
        })
        .collect()
}

#[test]
fn prop_hq_table_core_action_stream_is_byte_identical_to_reference() {
    prop::check("hq-action-stream-equality", 12, |rng| {
        let n = 4 + rng.below(24) as usize;
        let mut subs: Vec<(Micros, TaskSpec, Micros)> = (0..n)
            .map(|i| {
                let t = rng.below(90) * SEC;
                let spec = TaskSpec {
                    tag: i as u64,
                    cores: 1 + rng.below(16) as u32,
                    time_request: (1 + rng.below(40)) * SEC,
                    time_limit: if rng.uniform() < 0.15 {
                        (1 + rng.below(4)) * SEC
                    } else {
                        1000 * SEC
                    },
                };
                let dur = (1 + rng.below(16)) * SEC / 2;
                (t, spec, dur)
            })
            .collect();
        subs.sort_by_key(|(t, ..)| *t);
        let submissions: Vec<(Micros, TaskSpec)> =
            subs.iter().map(|(t, s, _)| (*t, s.clone())).collect();
        let durations: Vec<Micros> = subs.iter().map(|(.., d)| *d).collect();
        let alloc_delay = (1 + rng.below(20)) * SEC;
        let alloc_life = (60 + rng.below(300)) * SEC;
        let cfg = AutoAllocConfig {
            backlog: 1 + rng.below(3) as u32,
            workers_per_alloc: 1 + rng.below(2) as u32,
            max_worker_count: 2 + rng.below(4) as u32,
            alloc_request: JobRequest::new(16, 16, alloc_life),
            dispatch_latency: 1 * MS,
        };
        let mut indexed = HqCore::new(cfg.clone());
        let mut reference = ReferenceHqCore::new(cfg);
        let a = collect_hq_action_stream(&mut indexed, &submissions,
                                         &durations, alloc_delay, alloc_life);
        let b = collect_hq_action_stream(&mut reference, &submissions,
                                         &durations, alloc_delay, alloc_life);
        assert_eq!(a.len(), b.len(),
                   "action stream lengths diverged: {} vs {}",
                   a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x, y, "action stream diverged at index {i}");
        }
    });
}

#[test]
fn prop_hq_total_makespan_not_worse_for_slow_apps() {
    // For the compute-heavy apps the paper's claim must hold across
    // seeds on a quiet cluster ("outperforms or is comparable"): HQ's
    // experiment-level makespan <= SLURM's, with 10% comparability slack.
    prop::check("hq-wins-slow", 6, |rng| {
        let mut cfg = random_cfg(rng);
        cfg.app = if rng.uniform() < 0.5 { App::Gs2 } else {
            App::Eigen5000
        };
        cfg.queue_depth = 2;
        cfg.n_evals = 8;
        cfg.overheads.bg_interarrival = Micros::MAX;
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        assert!(
            (h.makespan() as f64) <= (s.makespan() as f64) * 1.10,
            "HQ {} vs SLURM {}", h.makespan(), s.makespan()
        );
    });
}
