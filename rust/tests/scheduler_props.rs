//! Property tests on scheduler invariants (util::prop harness): random
//! workloads through the sim-plane experiment runners must satisfy the
//! structural properties of correct scheduling regardless of seed.

use uqsched::cluster::ClusterSpec;
use uqsched::clock::{Micros, SEC};
use uqsched::experiments::{run_naive_slurm, run_umbridge_hq,
                           run_umbridge_slurm, Config};
use uqsched::util::prop;
use uqsched::workload::App;

fn random_cfg(rng: &mut uqsched::util::Rng) -> Config {
    let apps = App::all();
    let app = apps[rng.below(4) as usize];
    let qd = [1usize, 2, 3, 10][rng.below(4) as usize];
    let mut cfg = Config::paper(app, qd, rng.next_u64());
    cfg.n_evals = 5 + rng.below(15);
    cfg.cluster = ClusterSpec::small(4 + rng.below(8) as usize);
    // Mixed quiet/busy clusters.
    if rng.uniform() < 0.5 {
        cfg.overheads.bg_interarrival = Micros::MAX;
    } else {
        cfg.overheads.bg_interarrival = 100 * SEC;
    }
    cfg
}

#[test]
fn prop_all_evaluations_complete_exactly_once() {
    prop::check("complete-once", 12, |rng| {
        let cfg = random_cfg(rng);
        for exp in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg)] {
            assert_eq!(exp.records.len() as u64, cfg.n_evals,
                       "{}: wrong record count", exp.label);
            let mut tags: Vec<u64> =
                exp.records.iter().map(|r| r.tag).collect();
            tags.sort();
            tags.dedup();
            assert_eq!(tags.len() as u64, cfg.n_evals,
                       "{}: duplicated/lost tags", exp.label);
        }
    });
}

#[test]
fn prop_time_ordering_per_job() {
    prop::check("time-ordering", 12, |rng| {
        let cfg = random_cfg(rng);
        for exp in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg),
                    run_umbridge_slurm(&cfg)] {
            for r in &exp.records {
                assert!(r.submit <= r.start, "{}: submit > start",
                        exp.label);
                assert!(r.start <= r.end, "{}: start > end", exp.label);
                assert!(r.cpu <= r.makespan() + 1,
                        "{}: cpu {} > makespan {}", exp.label, r.cpu,
                        r.makespan());
            }
        }
    });
}

#[test]
fn prop_slr_at_least_one() {
    prop::check("slr>=1", 10, |rng| {
        let cfg = random_cfg(rng);
        for exp in [run_naive_slurm(&cfg), run_umbridge_hq(&cfg)] {
            for r in &exp.records {
                assert!(r.slr() >= 1.0 - 1e-9, "{}: SLR {}", exp.label,
                        r.slr());
            }
            assert!(exp.slr() >= 0.0);
        }
    });
}

#[test]
fn prop_makespan_at_least_critical_path() {
    // The experiment makespan can never beat total work / parallelism.
    prop::check("critical-path", 8, |rng| {
        let mut cfg = random_cfg(rng);
        cfg.overheads.bg_interarrival = Micros::MAX; // isolate the bound
        let exp = run_naive_slurm(&cfg);
        let total_cpu: u64 = exp.records.iter().map(|r| r.cpu).sum();
        let lower = total_cpu / (cfg.queue_depth as u64).max(1);
        assert!(exp.makespan() + SEC >= lower,
                "makespan {} < critical path {}", exp.makespan(), lower);
    });
}

#[test]
fn prop_same_seed_same_records() {
    prop::check("determinism", 6, |rng| {
        let cfg = random_cfg(rng);
        let a = run_umbridge_hq(&cfg);
        let b = run_umbridge_hq(&cfg);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x, y);
        }
    });
}

#[test]
fn prop_hq_total_makespan_not_worse_for_slow_apps() {
    // For the compute-heavy apps the paper's claim must hold across
    // seeds on a quiet cluster ("outperforms or is comparable"): HQ's
    // experiment-level makespan <= SLURM's, with 10% comparability slack.
    prop::check("hq-wins-slow", 6, |rng| {
        let mut cfg = random_cfg(rng);
        cfg.app = if rng.uniform() < 0.5 { App::Gs2 } else {
            App::Eigen5000
        };
        cfg.queue_depth = 2;
        cfg.n_evals = 8;
        cfg.overheads.bg_interarrival = Micros::MAX;
        let s = run_naive_slurm(&cfg);
        let h = run_umbridge_hq(&cfg);
        assert!(
            (h.makespan() as f64) <= (s.makespan() as f64) * 1.10,
            "HQ {} vs SLURM {}", h.makespan(), s.makespan()
        );
    });
}
