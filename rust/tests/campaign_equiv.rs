//! Campaign-plane equivalence and determinism.
//!
//! 1. The generic campaign drivers with the `FixedDepth` submitter must
//!    reproduce the PR 1 experiment drivers (`experiments::reference`)
//!    **record-for-record** — same `Experiment` records, same seed — for
//!    all four apps on every scheduler path.  This pins the refactor:
//!    the paper's protocol is now *one instance* of the campaign plane,
//!    not a separate code path.
//! 2. Open-ended policies (bursty, adaptive) must be pure functions of
//!    their seed: same seed, same records; different seed, different
//!    stream.
//! 3. The third scheduler (`worksteal`), which has no PR 1 reference,
//!    must honour the same contract: complete streams, deterministic
//!    under seed, distinct streams under distinct seeds.

use uqsched::campaign::{
    self, AdaptiveBayes, CampaignConfig, FixedDepth, Mlda, MldaLevel,
    PoissonBurst, Sink, SlurmMode, StageInOut, Submitter, UserMix,
    UserStream,
};
use uqsched::clock::{Micros, SEC};
use uqsched::cluster::ClusterSpec;
use uqsched::experiments::{
    reference, run_naive_slurm, run_umbridge_hq, run_umbridge_slurm, Config,
};
use uqsched::metrics::JobRecord;
use uqsched::workload::App;

fn small_cfg(app: App, queue_depth: usize, n_evals: u64, seed: u64) -> Config {
    let mut c = Config::paper(app, queue_depth, seed);
    c.n_evals = n_evals;
    c.cluster = ClusterSpec::small(8);
    // Light background load: cheap, but keeps the stochastic arrival
    // path exercised so the equivalence covers the rng interleaving.
    c.overheads.bg_interarrival = 300 * SEC;
    c
}

fn assert_records_equal(label: &str, a: &[JobRecord], b: &[JobRecord]) {
    assert_eq!(
        a.len(),
        b.len(),
        "{label}: record count {} vs {}",
        a.len(),
        b.len()
    );
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x, y, "{label}: record {i} diverged");
    }
}

#[test]
fn fixed_depth_matches_reference_all_apps_naive_slurm() {
    for app in App::all() {
        let n = if app == App::Gs2 { 8 } else { 12 };
        let cfg = small_cfg(app, 2, n, 11);
        let new = run_naive_slurm(&cfg);
        let old = reference::run_naive_slurm(&cfg);
        assert_records_equal(&format!("naive-slurm/{}", app.label()),
                             &new.records, &old.records);
    }
}

#[test]
fn fixed_depth_matches_reference_all_apps_umbridge_slurm() {
    for app in App::all() {
        let n = if app == App::Gs2 { 8 } else { 12 };
        let cfg = small_cfg(app, 2, n, 11);
        let new = run_umbridge_slurm(&cfg);
        let old = reference::run_umbridge_slurm(&cfg);
        assert_records_equal(&format!("umbridge-slurm/{}", app.label()),
                             &new.records, &old.records);
    }
}

#[test]
fn fixed_depth_matches_reference_all_apps_hq() {
    for app in App::all() {
        let n = if app == App::Gs2 { 8 } else { 12 };
        let cfg = small_cfg(app, 2, n, 11);
        let new = run_umbridge_hq(&cfg);
        let old = reference::run_umbridge_hq(&cfg);
        assert_records_equal(&format!("hq/{}", app.label()),
                             &new.records, &old.records);
    }
}

#[test]
fn fixed_depth_matches_reference_deeper_queue_and_other_seeds() {
    // The paper's second configuration (10 jobs in the queue) plus a
    // couple of seeds, on the cheapest app to keep the suite fast.
    for seed in [1u64, 7, 42] {
        let cfg = small_cfg(App::Eigen100, 10, 20, seed);
        assert_records_equal(
            &format!("naive-slurm/depth10/seed{seed}"),
            &run_naive_slurm(&cfg).records,
            &reference::run_naive_slurm(&cfg).records,
        );
        assert_records_equal(
            &format!("hq/depth10/seed{seed}"),
            &run_umbridge_hq(&cfg).records,
            &reference::run_umbridge_hq(&cfg).records,
        );
    }
}

#[test]
fn fixed_depth_matches_reference_on_paper_cluster() {
    // One cell on the full Hamilton8 cluster with paper background load
    // — the heaviest rng interleaving the reference driver supports.
    let mut cfg = Config::paper(App::Eigen5000, 2, 3);
    cfg.n_evals = 8;
    assert_records_equal(
        "naive-slurm/hamilton8",
        &run_naive_slurm(&cfg).records,
        &reference::run_naive_slurm(&cfg).records,
    );
    assert_records_equal(
        "hq/hamilton8",
        &run_umbridge_hq(&cfg).records,
        &reference::run_umbridge_hq(&cfg).records,
    );
}

// ---------------------------------------------------------------------------
// Determinism under seed for the open-ended policies.
// ---------------------------------------------------------------------------

fn bursty_records(seed: u64) -> Vec<JobRecord> {
    let mut cfg = CampaignConfig::paper(App::Gp, 4, seed);
    cfg.cluster = ClusterSpec::small(8);
    cfg.overheads.bg_interarrival = 300 * SEC;
    cfg.registration_jobs = 0;
    let mut sub = PoissonBurst::new(App::Gp, 40, 2 * SEC, (1, 4), seed);
    campaign::run_hq(&cfg, &mut sub).experiment.records
}

#[test]
fn bursty_stream_is_deterministic_under_seed() {
    let a = bursty_records(5);
    let b = bursty_records(5);
    assert_records_equal("bursty/seed5", &a, &b);
    assert_eq!(a.len(), 40);
    let c = bursty_records(6);
    assert_ne!(a, c, "different seed must change the stream");
}

fn adaptive_records(seed: u64) -> Vec<JobRecord> {
    let mut cfg = CampaignConfig::paper(App::Gs2, 4, seed);
    cfg.cluster = ClusterSpec::small(8);
    cfg.overheads.bg_interarrival = 300 * SEC;
    let mut sub =
        AdaptiveBayes::new(App::Gs2, 48, seed).with_batches(8, 4, 16);
    campaign::run_hq(&cfg, &mut sub).experiment.records
}

#[test]
fn adaptive_stream_is_deterministic_under_seed() {
    let a = adaptive_records(9);
    let b = adaptive_records(9);
    assert_records_equal("adaptive/seed9", &a, &b);
    assert!(!a.is_empty() && a.len() <= 48);
    let c = adaptive_records(10);
    assert_ne!(a, c, "different seed must change the stream");
}

#[test]
fn adaptive_batch_sizes_depend_on_results() {
    // Same seed but different budgets/batch clamps produce different
    // round structure; and against a heteroskedastic app (gs2) the
    // policy must issue more than one round before converging.
    let mut cfg = CampaignConfig::paper(App::Gs2, 4, 3);
    cfg.cluster = ClusterSpec::small(8);
    cfg.overheads.bg_interarrival = 300 * SEC;
    let mut sub = AdaptiveBayes::new(App::Gs2, 64, 3).with_batches(6, 4, 16);
    let r = campaign::run_hq(&cfg, &mut sub);
    assert!(sub.rounds() > 1, "gs2 variance must force extra rounds");
    assert_eq!(r.metrics.completed, r.experiment.records.len() as u64);
}

fn worksteal_records(seed: u64) -> Vec<JobRecord> {
    let mut cfg = CampaignConfig::paper(App::Gp, 4, seed);
    cfg.cluster = ClusterSpec::small(8);
    cfg.overheads.bg_interarrival = 300 * SEC;
    cfg.registration_jobs = 0;
    let mut sub = PoissonBurst::new(App::Gp, 40, 2 * SEC, (1, 4), seed);
    campaign::run_worksteal(&cfg, &mut sub).experiment.records
}

#[test]
fn worksteal_stream_is_deterministic_under_seed() {
    let a = worksteal_records(5);
    let b = worksteal_records(5);
    assert_records_equal("worksteal/seed5", &a, &b);
    assert_eq!(a.len(), 40);
    let c = worksteal_records(6);
    assert_ne!(a, c, "different seed must change the stream");
}

#[test]
fn worksteal_completes_the_paper_protocol_on_every_app() {
    // No PR 1 reference exists for the third scheduler; pin the
    // protocol-level contract instead: the fixed-depth campaign
    // completes every evaluation exactly once on all four apps.
    for app in App::all() {
        let n = if app == App::Gs2 { 8 } else { 12 };
        let cfg = small_cfg(app, 2, n, 11);
        let mut sub = FixedDepth::new(app, n, 2, cfg.seed);
        let r = campaign::run_worksteal(&cfg.campaign(), &mut sub);
        assert_eq!(r.experiment.records.len() as u64, n,
                   "worksteal/{}", app.label());
        let mut tags: Vec<u64> =
            r.experiment.records.iter().map(|x| x.tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len() as u64, n, "worksteal/{}: tags", app.label());
        assert_eq!(r.metrics.scheduler, "worksteal");
    }
}

#[test]
fn user_mix_is_deterministic_and_complete() {
    let run = |seed: u64| {
        let mut cfg = CampaignConfig::paper(App::Gp, 4, seed);
        cfg.cluster = ClusterSpec::small(8);
        cfg.overheads.bg_interarrival = 300 * SEC;
        let mut sub = UserMix::new(
            vec![
                UserStream {
                    user: 0,
                    app: App::Gp,
                    n_evals: 10,
                    queue_depth: 2,
                },
                UserStream {
                    user: 1,
                    app: App::Eigen100,
                    n_evals: 10,
                    queue_depth: 2,
                },
            ],
            seed,
        );
        campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native)
    };
    let a = run(4);
    let b = run(4);
    assert_records_equal("usermix/seed4", &a.experiment.records,
                         &b.experiment.records);
    assert_eq!(a.experiment.records.len(), 20);
    assert_eq!(a.metrics.per_user.len(), 2);
}

// ---------------------------------------------------------------------------
// DAG plane: seed determinism and the zero-edge equivalence pin.
// ---------------------------------------------------------------------------

fn dag_cfg(app: App, seed: u64) -> CampaignConfig {
    let mut cfg = CampaignConfig::paper(app, 4, seed);
    cfg.cluster = ClusterSpec::small(8);
    cfg.overheads.bg_interarrival = 300 * SEC;
    cfg.registration_jobs = 0;
    cfg
}

fn mlda_records(seed: u64) -> Vec<JobRecord> {
    let levels = vec![
        MldaLevel { count: 12, runtime_scale: 0.5 },
        MldaLevel { count: 8, runtime_scale: 1.0 },
        MldaLevel { count: 4, runtime_scale: 2.0 },
    ];
    let mut sub =
        Mlda::new(App::Gp, levels, seed).with_occupancy(3, 1, 12);
    campaign::run_hq(&dag_cfg(App::Gp, seed), &mut sub).experiment.records
}

#[test]
fn mlda_stream_is_deterministic_under_seed() {
    let a = mlda_records(5);
    let b = mlda_records(5);
    assert_records_equal("mlda/seed5", &a, &b);
    assert!(!a.is_empty());
    let c = mlda_records(6);
    assert_ne!(a, c, "different seed must change the stream");
}

fn stageio_records(seed: u64) -> Vec<JobRecord> {
    let mut sub = StageInOut::new(App::Gp, 4, 3, 2, seed);
    campaign::run_hq(&dag_cfg(App::Gp, seed), &mut sub).experiment.records
}

#[test]
fn stageio_stream_is_deterministic_under_seed() {
    let a = stageio_records(5);
    let b = stageio_records(5);
    assert_records_equal("stageio/seed5", &a, &b);
    assert_eq!(a.len(), 4 * (3 + 2));
    let c = stageio_records(6);
    assert_ne!(a, c, "different seed must change the stream");
}

/// Wrapper that re-routes every plain submission through the dependency
/// layer with an empty parent list (`Sink::gate_pending`) — the
/// zero-edge DAG path.  A dependency plane that perturbs campaigns
/// without dependencies would be a regression; this pins the records
/// bit-for-bit against the ungated kernel.
struct GateAll<S>(S);

impl<S: Submitter> Submitter for GateAll<S> {
    fn label(&self) -> &'static str {
        self.0.label()
    }

    fn start(&mut self, sink: &mut Sink) {
        self.0.start(sink);
        sink.gate_pending();
    }

    fn wake(&mut self, t: Micros, token: u64, sink: &mut Sink) {
        self.0.wake(t, token, sink);
        sink.gate_pending();
    }

    fn completed(&mut self, t: Micros, rec: &JobRecord, sink: &mut Sink) {
        self.0.completed(t, rec, sink);
        sink.gate_pending();
    }

    fn registration_completed(&mut self, t: Micros, sink: &mut Sink) {
        self.0.registration_completed(t, sink);
        sink.gate_pending();
    }

    fn finished(&self, completed: u64) -> bool {
        self.0.finished(completed)
    }
}

#[test]
fn zero_edge_gating_is_record_identical_to_the_plain_kernel() {
    let cfg = dag_cfg(App::Eigen100, 11);
    let run = |gated: bool, which: &str| -> Vec<JobRecord> {
        let inner = FixedDepth::new(App::Eigen100, 16, 2, cfg.seed);
        let res = if gated {
            let mut sub = GateAll(inner);
            match which {
                "slurm" => {
                    campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native)
                }
                "hq" => campaign::run_hq(&cfg, &mut sub),
                "worksteal" => campaign::run_worksteal(&cfg, &mut sub),
                "gang" => campaign::run_gang(&cfg, &mut sub),
                _ => campaign::run_edf(&cfg, &mut sub),
            }
        } else {
            let mut sub = inner;
            match which {
                "slurm" => {
                    campaign::run_slurm(&cfg, &mut sub, SlurmMode::Native)
                }
                "hq" => campaign::run_hq(&cfg, &mut sub),
                "worksteal" => campaign::run_worksteal(&cfg, &mut sub),
                "gang" => campaign::run_gang(&cfg, &mut sub),
                _ => campaign::run_edf(&cfg, &mut sub),
            }
        };
        assert_eq!(res.metrics.completed, 16);
        if gated {
            assert_eq!(res.metrics.dep_edges, 0, "zero-edge run");
            assert_eq!(res.metrics.skipped, 0);
        }
        res.experiment.records
    };
    for which in ["slurm", "hq", "worksteal", "edf", "gang"] {
        let plain = run(false, which);
        let gated = run(true, which);
        assert_records_equal(&format!("zero-edge/{which}"), &plain, &gated);
    }
}
