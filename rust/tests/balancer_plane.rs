//! Balancer-plane integration tests, artifact-free: synthetic models
//! over the in-process `LocalBackend` exercise the full serving plane —
//! multi-model routing, learned contracts, the forwarder pool, registry
//! leases, backpressure (503 + Retry-After) and abandoned-work
//! cancellation — with no PJRT, no scheduler daemon and no port files.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use uqsched::coordinator::{BalancerConfig, LoadBalancer, LocalBackend};
use uqsched::httpd::{HttpClient, Request};
use uqsched::json::{self, Value};
use uqsched::models::SyntheticModel;
use uqsched::sched::LivePolicy;
use uqsched::umbridge::{HttpModel, Model};

/// alpha: [2] -> [1]; beta: [3] -> [2,1]; slow-*: [1] -> [1] with the
/// given service time in ms (e.g. "slow-500").
fn factory() -> uqsched::coordinator::ModelFactory {
    Arc::new(|name: &str| {
        let m: Arc<dyn Model> = match name {
            "alpha" => Arc::new(SyntheticModel::new("alpha", &[2], &[1])),
            "beta" => Arc::new(SyntheticModel::new("beta", &[3], &[2, 1])),
            slow if slow.starts_with("slow-") => {
                let ms: u64 = slow["slow-".len()..].parse().unwrap_or(100);
                Arc::new(
                    SyntheticModel::new(slow, &[1], &[1])
                        .with_delay(Duration::from_millis(ms)),
                )
            }
            other => anyhow::bail!("unknown test model '{other}'"),
        };
        Ok(m)
    })
}

fn start(cfg: BalancerConfig) -> LoadBalancer {
    LoadBalancer::start(cfg, LocalBackend::new(factory())).expect("balancer")
}

fn wait_servers(lb: &LoadBalancer, n: usize) {
    let t0 = Instant::now();
    while lb.registry().total() < n {
        assert!(t0.elapsed() < Duration::from_secs(20),
                "servers failed to register");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn eval_body(model: &str, inputs: &[Vec<f64>]) -> String {
    json::write(&Value::obj(vec![
        ("name", Value::str(model)),
        ("input", Value::from_f64s2(inputs)),
        ("config", Value::Obj(Default::default())),
    ]))
}

#[test]
fn multi_model_mixed_clients() {
    let mut lb = start(BalancerConfig {
        models: vec!["alpha".into(), "beta".into()],
        max_servers: 2,
        forwarders: 4,
        ..Default::default()
    });
    let url = lb.url();
    wait_servers(&lb, 2); // warm start: one per model

    // Mixed concurrent clients, routed by name through one front door.
    let threads: Vec<_> = ["alpha", "beta", "alpha", "beta"]
        .iter()
        .enumerate()
        .map(|(t, name)| {
            let url = url.clone();
            let name = name.to_string();
            std::thread::spawn(move || {
                let mut m = HttpModel::connect(&url, &name).unwrap();
                let cfgv = Value::Obj(Default::default());
                for i in 0..5 {
                    let x: Vec<f64> = if name == "alpha" {
                        vec![t as f64, i as f64]
                    } else {
                        vec![t as f64, i as f64, 1.0]
                    };
                    let sum: f64 = x.iter().sum();
                    let out = m.evaluate(&[x], &cfgv)
                        .unwrap_or_else(|e| panic!("{name} t{t} i{i}: {e:#}"));
                    // SyntheticModel: output j filled with sum + j.
                    assert_eq!(out[0][0], sum, "{name} routed wrong");
                    if name == "beta" {
                        assert_eq!(out.len(), 2);
                        assert_eq!(out[1][0], sum + 1.0);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // /Info aggregates both models.
    let mut any = HttpModel::connect(&url, "alpha").unwrap();
    let (_ver, names) = any.info().unwrap();
    assert!(names.contains(&"alpha".to_string()));
    assert!(names.contains(&"beta".to_string()));
    // Contracts were learned at registration, per model.
    assert_eq!(any.input_sizes().unwrap(), vec![2]);
    let mut b = HttpModel::connect(&url, "beta").unwrap();
    assert_eq!(b.output_sizes().unwrap(), vec![2, 1]);

    // Per-model stats counted independently.
    assert_eq!(lb.stats().model("alpha").unwrap()
                   .served.load(Ordering::Relaxed), 10);
    assert_eq!(lb.stats().model("beta").unwrap()
                   .served.load(Ordering::Relaxed), 10);
    assert_eq!(lb.requests_served.load(Ordering::Relaxed), 20);
    lb.shutdown();
}

/// The serving plane runs on the `SchedulerCore` seam: the same
/// artifact-free workload must serve end-to-end under every live
/// policy, not just the default FCFS core.
#[test]
fn alternate_schedulers_serve_end_to_end() {
    for policy in [LivePolicy::WorkSteal, LivePolicy::Edf,
                   LivePolicy::Gang] {
        let mut lb = start(BalancerConfig {
            models: vec!["alpha".into(), "beta".into()],
            max_servers: 2,
            forwarders: 4,
            scheduler: policy,
            ..Default::default()
        });
        assert_eq!(lb.scheduler(), policy);
        let url = lb.url();
        wait_servers(&lb, 2);

        let threads: Vec<_> = ["alpha", "beta"]
            .iter()
            .map(|name| {
                let url = url.clone();
                let name = name.to_string();
                std::thread::spawn(move || {
                    let mut m = HttpModel::connect(&url, &name).unwrap();
                    let cfgv = Value::Obj(Default::default());
                    for i in 0..5 {
                        let x: Vec<f64> = if name == "alpha" {
                            vec![i as f64, 1.0]
                        } else {
                            vec![i as f64, 1.0, 2.0]
                        };
                        let sum: f64 = x.iter().sum();
                        let out = m.evaluate(&[x], &cfgv).unwrap_or_else(
                            |e| panic!("{name} i{i} ({policy:?}): {e:#}"));
                        assert_eq!(out[0][0], sum,
                                   "{name} routed wrong under {policy:?}");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(lb.stats().model("alpha").unwrap()
                       .served.load(Ordering::Relaxed), 5);
        assert_eq!(lb.stats().model("beta").unwrap()
                       .served.load(Ordering::Relaxed), 5);
        // /Stats names the policy serving this front door.
        let doc = lb.stats_json();
        assert_eq!(doc.get("scheduler").and_then(|v| v.as_str()),
                   Some(policy.label()));
        lb.shutdown();
    }
}

#[test]
fn per_job_servers_retire_and_respawn() {
    let mut lb = start(BalancerConfig {
        models: vec!["alpha".into()],
        max_servers: 2,
        persistent_servers: false,
        ..Default::default()
    });
    let url = lb.url();
    wait_servers(&lb, 1);
    let mut m = HttpModel::connect(&url, "alpha").unwrap();
    let cfgv = Value::Obj(Default::default());
    for i in 0..4 {
        let out = m.evaluate(&[vec![i as f64, 1.0]], &cfgv).expect("evaluate");
        assert_eq!(out[0][0], i as f64 + 1.0);
    }
    // Every evaluation retired its server; new ones were spawned.
    assert!(lb.registry().registered_total() >= 4,
            "expected several registrations, got {}",
            lb.registry().registered_total());
    assert!(lb.registry().removed_total() >= 3);
    lb.shutdown();
}

#[test]
fn backpressure_rejects_with_retry_after_then_drains() {
    let mut lb = start(BalancerConfig {
        models: vec!["slow-600".into()],
        max_servers: 1,
        queue_capacity: 1,
        forwarders: 2,
        ..Default::default()
    });
    let url = lb.url();
    wait_servers(&lb, 1);

    // A occupies the single server for ~600 ms.
    let a = {
        let url = url.clone();
        std::thread::spawn(move || {
            let mut m = HttpModel::connect(&url, "slow-600").unwrap();
            m.evaluate(&[vec![1.0]], &Value::Obj(Default::default()))
                .expect("A")
        })
    };
    std::thread::sleep(Duration::from_millis(150));
    // B fills the queue (capacity 1).
    let b = {
        let url = url.clone();
        std::thread::spawn(move || {
            let mut m = HttpModel::connect(&url, "slow-600").unwrap();
            m.evaluate(&[vec![2.0]], &Value::Obj(Default::default()))
                .expect("B")
        })
    };
    std::thread::sleep(Duration::from_millis(150));
    // C must bounce: 503 + Retry-After, not unbounded queue growth.
    let mut raw = HttpClient::connect(&url).unwrap();
    let resp = raw
        .request(&Request::post("/Evaluate", &eval_body("slow-600",
                                                        &[vec![3.0]])))
        .unwrap();
    assert_eq!(resp.status, 503, "expected backpressure, got {}",
               resp.status);
    let retry = resp
        .headers
        .get("retry-after")
        .expect("503 must carry Retry-After");
    // Derived from the live queue-wait p50, clamped to [1, 30] s —
    // never a bare constant outside that window.
    let secs: u32 = retry.parse().expect("Retry-After must be integral");
    assert!((1..=30).contains(&secs),
            "Retry-After {secs} outside the [1, 30] s clamp");

    // The queue drains: A and B complete, and a retry of C succeeds.
    assert_eq!(a.join().unwrap()[0][0], 1.0);
    assert_eq!(b.join().unwrap()[0][0], 2.0);
    let mut m = HttpModel::connect(&url, "slow-600").unwrap();
    let out = m
        .evaluate(&[vec![3.0]], &Value::Obj(Default::default()))
        .expect("C retry");
    assert_eq!(out[0][0], 3.0);

    let st = lb.stats().model("slow-600").unwrap();
    assert!(st.rejected.load(Ordering::Relaxed) >= 1);
    assert_eq!(st.served.load(Ordering::Relaxed), 3);
    lb.shutdown();
}

#[test]
fn client_timeout_cancels_queued_work() {
    let mut lb = start(BalancerConfig {
        models: vec!["slow-500".into()],
        max_servers: 1,
        forwarders: 2,
        request_timeout: Duration::from_millis(150),
        ..Default::default()
    });
    let url = lb.url();
    wait_servers(&lb, 1);

    // A is dispatched (server busy for 500 ms); B waits in the queue.
    // Both clients give up at 150 ms; B's item must be cancelled and
    // skipped at dispatch instead of burning the server on a result
    // nobody reads.
    let post = |tag: f64| {
        let url = url.clone();
        std::thread::spawn(move || {
            let mut raw = HttpClient::connect(&url).unwrap();
            raw.request(&Request::post("/Evaluate",
                                       &eval_body("slow-500",
                                                  &[vec![tag]])))
                .unwrap()
        })
    };
    let a = post(1.0);
    std::thread::sleep(Duration::from_millis(60));
    let b = post(2.0);
    assert_eq!(a.join().unwrap().status, 504, "A should time out");
    assert_eq!(b.join().unwrap().status, 504, "B should time out");

    // Let the server free up and the forwarder observe B's cancellation.
    let t0 = Instant::now();
    let st = lb.stats().model("slow-500").unwrap();
    while st.cancelled.load(Ordering::Relaxed) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "cancelled item was never skipped");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(st.timed_out.load(Ordering::Relaxed), 2);
    // Only A's forward ever ran: B was skipped, the server never
    // evaluated it.
    assert_eq!(st.served.load(Ordering::Relaxed), 1);
    lb.shutdown();
}

/// A model whose server "dies" when the shared kill switch is armed:
/// the evaluate panics its connection thread, so the socket drops
/// mid-request exactly like a crashed server process.  The switch
/// clears on use — the next attempt (on a replacement server)
/// succeeds.
struct KillableModel {
    inner: SyntheticModel,
    kill_next: Arc<AtomicBool>,
}

impl Model for KillableModel {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn input_sizes(&self) -> Vec<usize> {
        self.inner.input_sizes()
    }
    fn output_sizes(&self) -> Vec<usize> {
        self.inner.output_sizes()
    }
    fn evaluate(&self, inputs: &[Vec<f64>], config: &Value)
                -> anyhow::Result<Vec<Vec<f64>>> {
        if self.kill_next.swap(false, Ordering::SeqCst) {
            panic!("injected server death (test)");
        }
        self.inner.evaluate(inputs, config)
    }
}

#[test]
fn server_killed_mid_evaluation_recovers_on_replacement() {
    let kill = Arc::new(AtomicBool::new(false));
    let kill2 = kill.clone();
    let factory: uqsched::coordinator::ModelFactory =
        Arc::new(move |name: &str| {
            if name != "mortal" {
                anyhow::bail!("unknown test model '{name}'");
            }
            Ok(Arc::new(KillableModel {
                inner: SyntheticModel::new("mortal", &[2], &[1]),
                kill_next: kill2.clone(),
            }) as Arc<dyn Model>)
        });
    let mut lb = LoadBalancer::start(
        BalancerConfig {
            models: vec!["mortal".into()],
            max_servers: 2,
            forwarders: 2,
            ..Default::default()
        },
        LocalBackend::new(factory),
    )
    .expect("balancer");
    let url = lb.url();
    wait_servers(&lb, 1);

    let mut m = HttpModel::connect(&url, "mortal").unwrap();
    let cfgv = Value::Obj(Default::default());
    let out = m.evaluate(&[vec![1.0, 2.0]], &cfgv).expect("healthy");
    assert_eq!(out[0][0], 3.0);

    // Arm the switch: the next forward dies with its server.  The
    // balancer must retire the dead server, requeue the evaluation
    // through its scheduler core, and complete it on a replacement —
    // the client sees one slower success, never an error.
    kill.store(true, Ordering::SeqCst);
    let out = m
        .evaluate(&[vec![5.0, 7.0]], &cfgv)
        .expect("must complete on a replacement server");
    assert_eq!(out[0][0], 12.0);

    let st = lb.stats().model("mortal").unwrap();
    assert_eq!(st.retries.load(Ordering::Relaxed), 1);
    assert!(st.worker_lost.load(Ordering::Relaxed) >= 1);
    assert_eq!(st.quarantined.load(Ordering::Relaxed), 0);
    assert_eq!(st.served.load(Ordering::Relaxed), 2);
    assert_eq!(st.errors.load(Ordering::Relaxed), 0);
    assert_eq!(st.retry_backoff.count(), 1,
               "the retry's backoff must be recorded");
    lb.shutdown();
}

/// Every server of this model dies on evaluate: the retry budget
/// (2 attempts by default) must exhaust and surface an error — a
/// quarantined evaluation is reported, never silently dropped or
/// retried forever.
struct DoomedModel {
    inner: SyntheticModel,
}

impl Model for DoomedModel {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn input_sizes(&self) -> Vec<usize> {
        self.inner.input_sizes()
    }
    fn output_sizes(&self) -> Vec<usize> {
        self.inner.output_sizes()
    }
    fn evaluate(&self, _inputs: &[Vec<f64>], _config: &Value)
                -> anyhow::Result<Vec<Vec<f64>>> {
        panic!("injected permanent server death (test)");
    }
}

#[test]
fn repeated_server_death_exhausts_retry_budget() {
    let factory: uqsched::coordinator::ModelFactory =
        Arc::new(|name: &str| {
            if name != "doomed" {
                anyhow::bail!("unknown test model '{name}'");
            }
            Ok(Arc::new(DoomedModel {
                inner: SyntheticModel::new("doomed", &[1], &[1]),
            }) as Arc<dyn Model>)
        });
    let mut lb = LoadBalancer::start(
        BalancerConfig {
            models: vec!["doomed".into()],
            max_servers: 2,
            forwarders: 2,
            ..Default::default()
        },
        LocalBackend::new(factory),
    )
    .expect("balancer");
    let url = lb.url();
    wait_servers(&lb, 1);

    let mut m = HttpModel::connect(&url, "doomed").unwrap();
    let cfgv = Value::Obj(Default::default());
    let out = m.evaluate(&[vec![1.0]], &cfgv);
    assert!(out.is_err(), "budget exhausted: the error must surface");

    let st = lb.stats().model("doomed").unwrap();
    assert_eq!(st.retries.load(Ordering::Relaxed), 1,
               "one retry before the budget (2 attempts) exhausts");
    assert_eq!(st.quarantined.load(Ordering::Relaxed), 1);
    assert_eq!(st.errors.load(Ordering::Relaxed), 1);
    assert_eq!(st.served.load(Ordering::Relaxed), 0);
    lb.shutdown();
}

/// Multi-shard drill: mixed clients across 3 models × 2 shards with a
/// mid-run server kill.  Every accepted request must resolve (the killed
/// evaluation retries on a replacement server), and the front door's
/// /Stats totals must equal the sum of the per-shard snapshots.
#[test]
fn multi_shard_drill_no_request_lost_and_snapshots_sum() {
    let kill = Arc::new(AtomicBool::new(false));
    let kill2 = kill.clone();
    let factory: uqsched::coordinator::ModelFactory =
        Arc::new(move |name: &str| {
            if !name.starts_with("drill-") {
                anyhow::bail!("unknown test model '{name}'");
            }
            Ok(Arc::new(KillableModel {
                inner: SyntheticModel::new(name, &[2], &[1]),
                kill_next: kill2.clone(),
            }) as Arc<dyn Model>)
        });
    let names: Vec<String> = (0..3).map(|i| format!("drill-{i}")).collect();
    let mut lb = LoadBalancer::start(
        BalancerConfig {
            models: names.clone(),
            max_servers: 2,
            forwarders: 6,
            shards_per_model: 2,
            ..Default::default()
        },
        LocalBackend::new(factory),
    )
    .expect("balancer");
    let url = lb.url();
    wait_servers(&lb, 3);

    let evals = 20usize;
    let threads: Vec<_> = names
        .iter()
        .flat_map(|name| {
            (0..2usize).map(|c| {
                let url = url.clone();
                let name = name.clone();
                let kill = kill.clone();
                std::thread::spawn(move || {
                    let mut m = HttpModel::connect(&url, &name).unwrap();
                    let cfgv = Value::Obj(Default::default());
                    for i in 0..evals {
                        if c == 0 && i == evals / 2 && name.ends_with("-1") {
                            // Mid-run: the next forward dies with its
                            // server, whichever model it serves.
                            kill.store(true, Ordering::SeqCst);
                        }
                        let x = vec![c as f64, i as f64];
                        let sum: f64 = x.iter().sum();
                        let out = m.evaluate(&[x], &cfgv).unwrap_or_else(
                            |e| panic!("{name} c{c} i{i}: {e:#}"));
                        assert_eq!(out[0][0], sum, "{name} routed wrong");
                    }
                })
            }).collect::<Vec<_>>()
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // No accepted request lost: every one of the 120 evaluations
    // resolved successfully (the killed forward recovered via retry).
    assert_eq!(lb.requests_served.load(Ordering::Relaxed), 120);
    let total_retries: u64 = names
        .iter()
        .map(|m| lb.stats().model(m).unwrap()
                 .retries.load(Ordering::Relaxed))
        .sum();
    assert!(total_retries >= 1, "the mid-run kill must have forced a retry");

    // /Stats totals equal the sum of the per-shard snapshots.
    let doc = lb.stats_json();
    assert_eq!(doc.get("shards_per_model").and_then(|v| v.as_f64()),
               Some(2.0));
    let ms = doc.get("models").and_then(|v| v.as_arr()).expect("models");
    assert_eq!(ms.len(), 3);
    for row in ms {
        let name = row.get("name").and_then(|v| v.as_str()).unwrap();
        let shards = row.get("shards").and_then(|v| v.as_arr())
            .expect("per-shard snapshots");
        assert_eq!(shards.len(), 2, "{name}: one snapshot per shard");
        let snap_served: f64 = shards.iter()
            .map(|s| s.get("served").and_then(|v| v.as_f64()).unwrap())
            .sum();
        let snap_submitted: f64 = shards.iter()
            .map(|s| s.get("submitted").and_then(|v| v.as_f64()).unwrap())
            .sum();
        let served = row.get("served").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(served, 40.0, "{name} lost requests");
        assert_eq!(snap_served, served,
                   "{name}: /Stats total != sum of shard snapshots");
        assert_eq!(snap_submitted, 40.0,
                   "{name}: shard snapshots lost submissions");
    }
    lb.shutdown();
}

/// Skewed-mix drill for the power-of-two-choices shard pick: a 90/10
/// model mix (90 requests for `hot`, 10 for `cold`) over 2 shards per
/// model.  P2C compares the two admission-gate depths on every submit,
/// so within each model's group the queued work must stay balanced —
/// no shard may starve behind its sibling — and once servers appear,
/// every shard must actually dispatch its share.
#[test]
fn skewed_mix_p2c_keeps_every_shard_fed() {
    use std::sync::atomic::AtomicU64;
    use uqsched::coordinator::{BalancerStats, DispatchPlane, PlaneConfig,
                               Registry, SubmitOutcome};
    use uqsched::sched::realtime::RetryPolicy;
    use uqsched::umbridge::ModelContract;

    let names: Vec<String> = vec!["hot".into(), "cold".into()];
    let registry = Arc::new(Registry::new());
    let stats = Arc::new(BalancerStats::new(&names));
    let plane = DispatchPlane::start(
        PlaneConfig {
            models: names.clone(),
            shards_per_model: 2,
            queue_capacity: 256,
            scheduler: LivePolicy::Fcfs,
            retry: RetryPolicy::default(),
            request_timeout: Duration::from_secs(10),
            persistent_servers: true,
        },
        registry.clone(),
        stats,
        Arc::new(AtomicU64::new(0)),
    );

    // Phase 1 — admission balance. No workers yet, so gate depths are
    // exactly the queued counts: submit the skewed mix and check that
    // neither model's group let one shard run away.
    let mut handles = Vec::new();
    for i in 0..100usize {
        let model = if i % 10 == 9 { "cold" } else { "hot" };
        match plane.submit(model, format!("{model}:{i}")) {
            SubmitOutcome::Queued(h) => handles.push(h),
            _ => panic!("submit {i} rejected"),
        }
    }
    for model in ["hot", "cold"] {
        let total: u64 = if model == "hot" { 90 } else { 10 };
        assert_eq!(plane.queued_for(model), total as usize,
                   "{model}: lost work at admission");
        // Wait for the shard threads to publish their epoch-stamped
        // snapshots, then check the per-shard split: depth-compared
        // admission must keep the group level (45/45 and 5/5 here,
        // with a little slack for publish timing).
        let t0 = Instant::now();
        let queued = loop {
            let q: Vec<u64> =
                plane.counts_for(model).iter().map(|c| c.queued).collect();
            if q.iter().sum::<u64>() == total {
                break q;
            }
            assert!(t0.elapsed() < Duration::from_secs(10),
                    "{model}: snapshots never converged ({q:?})");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(queued.len(), 2);
        let (lo, hi) =
            (*queued.iter().min().unwrap(), *queued.iter().max().unwrap());
        assert!(hi - lo <= 2,
                "{model}: p2c admission split {queued:?} is unbalanced");
    }

    // Phase 2 — service balance. One server per model; drain everything
    // and require every shard of both groups to have dispatched work.
    let contract = ModelContract { input_sizes: vec![1], output_sizes: vec![1] };
    for (j, m) in names.iter().enumerate() {
        let ep = format!("skew-{j}");
        registry.register(&ep, m, &contract);
        plane.worker_up(&ep, m);
    }
    let mut served = 0usize;
    let deadline = Instant::now() + Duration::from_secs(30);
    while served < handles.len() {
        assert!(Instant::now() < deadline,
                "orders stalled at {served}/{}", handles.len());
        for s in 0..plane.shard_count() {
            while let Some(order) = plane.take_order(s, Duration::from_millis(5)) {
                plane.complete_order(order, Ok("ok".into()));
                served += 1;
            }
        }
    }
    for h in &handles {
        let r = h.wait_deadline(Instant::now() + Duration::from_secs(5))
            .expect("resolved");
        assert!(r.is_ok());
    }
    for model in ["hot", "cold"] {
        let counts = plane.counts_for(model);
        let total: u64 = if model == "hot" { 90 } else { 10 };
        let dispatched: Vec<u64> = counts.iter().map(|c| c.dispatched).collect();
        assert_eq!(dispatched.iter().sum::<u64>(), total, "{model}: lost work");
        assert!(dispatched.iter().all(|&d| d > 0),
                "{model}: a shard starved under the 90/10 mix \
                 (dispatched split {dispatched:?})");
        // P2C bounds the split: with depth-compared admission neither
        // shard may take more than ~2/3 of a 90-request stream the way
        // a stale or unlucky round-robin can.
        let (lo, hi) = (
            *dispatched.iter().min().unwrap(),
            *dispatched.iter().max().unwrap(),
        );
        assert!(hi - lo <= total / 3,
                "{model}: shard imbalance {dispatched:?} exceeds the p2c bound");
    }
    plane.shutdown();
}

/// Per-model FCFS must hold within each shard of a group: drive the
/// dispatch plane directly (3 models × 2 shards, one shared server per
/// model) and check every shard's order stream surfaces each model's
/// submissions in order.
#[test]
fn fcfs_order_holds_within_each_shard_of_a_group() {
    use std::collections::HashMap;
    use std::sync::atomic::AtomicU64;
    use uqsched::coordinator::{BalancerStats, DispatchPlane, PlaneConfig,
                               Registry, SubmitOutcome};
    use uqsched::sched::realtime::RetryPolicy;
    use uqsched::umbridge::ModelContract;

    let names: Vec<String> = (0..3).map(|i| format!("m{i}")).collect();
    let registry = Arc::new(Registry::new());
    let stats = Arc::new(BalancerStats::new(&names));
    let plane = DispatchPlane::start(
        PlaneConfig {
            models: names.clone(),
            shards_per_model: 2,
            queue_capacity: 64,
            scheduler: LivePolicy::Fcfs,
            retry: RetryPolicy::default(),
            request_timeout: Duration::from_secs(10),
            persistent_servers: true,
        },
        registry.clone(),
        stats,
        Arc::new(AtomicU64::new(0)),
    );
    let contract = ModelContract {
        input_sizes: vec![1],
        output_sizes: vec![1],
    };
    for (j, m) in names.iter().enumerate() {
        let ep = format!("fcfs-drill-{j}");
        registry.register(&ep, m, &contract);
        plane.worker_up(&ep, m);
    }
    let t0 = Instant::now();
    while names.iter().any(|m| plane.workers_for(m) < 1) {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "workers failed to announce");
        std::thread::sleep(Duration::from_millis(2));
    }

    let mut handles = Vec::new();
    for m in &names {
        for i in 0..8 {
            match plane.submit(m, format!("{m}:{i}")) {
                SubmitOutcome::Queued(h) => handles.push(h),
                _ => panic!("submit rejected"),
            }
        }
    }

    // Drain the order queues; within each (shard, model) stream the
    // submission index must be strictly increasing.
    let mut last_seen: HashMap<(usize, String), i64> = HashMap::new();
    let mut served = 0usize;
    let deadline = Instant::now() + Duration::from_secs(20);
    while served < handles.len() {
        assert!(Instant::now() < deadline,
                "orders stalled at {served}/{}", handles.len());
        for s in 0..plane.shard_count() {
            while let Some(order) =
                plane.take_order(s, Duration::from_millis(5))
            {
                let body = order.item().body().to_string();
                let (m, idx) = body.split_once(':').unwrap();
                let idx: i64 = idx.parse().unwrap();
                if let Some(prev) =
                    last_seen.insert((order.shard(), m.to_string()), idx)
                {
                    assert!(idx > prev,
                            "FCFS violated within shard {}: {m}:{idx} \
                             after {m}:{prev}", order.shard());
                }
                plane.complete_order(order, Ok("ok".into()));
                served += 1;
            }
        }
    }
    for h in &handles {
        let r = h.wait_deadline(Instant::now() + Duration::from_secs(5))
            .expect("resolved");
        assert!(r.is_ok());
    }
    plane.shutdown();
}

#[test]
fn stats_endpoint_reports_histograms() {
    let mut lb = start(BalancerConfig {
        models: vec!["alpha".into()],
        ..Default::default()
    });
    let url = lb.url();
    wait_servers(&lb, 1);
    let mut m = HttpModel::connect(&url, "alpha").unwrap();
    let cfgv = Value::Obj(Default::default());
    for _ in 0..3 {
        m.evaluate(&[vec![1.0, 2.0]], &cfgv).expect("evaluate");
    }

    let mut raw = HttpClient::connect(&url).unwrap();
    let resp = raw.request(&Request::get("/Stats")).unwrap();
    assert_eq!(resp.status, 200);
    let v = json::parse(resp.body_str().unwrap()).expect("stats json");
    let ms = v.get("models").and_then(|x| x.as_arr()).expect("models");
    assert_eq!(ms.len(), 1);
    let alpha = &ms[0];
    assert_eq!(alpha.get("name").and_then(|x| x.as_str()), Some("alpha"));
    assert_eq!(alpha.get("served").and_then(|x| x.as_f64()), Some(3.0));
    let qw = alpha.get("queue_wait").expect("queue_wait histogram");
    assert_eq!(qw.get("count").and_then(|x| x.as_f64()), Some(3.0));
    let fw = alpha.get("forward").expect("forward histogram");
    assert_eq!(fw.get("count").and_then(|x| x.as_f64()), Some(3.0));
    assert!(fw.get("p99_us").and_then(|x| x.as_f64()).unwrap() > 0.0);
    assert!(v.get("servers_total").is_some());
    lb.shutdown();
}

#[test]
fn unknown_model_and_cold_metadata() {
    let mut lb = start(BalancerConfig {
        models: vec!["alpha".into()],
        warm_start: false, // stay cold: nothing registers
        ..Default::default()
    });
    let url = lb.url();
    let mut raw = HttpClient::connect(&url).unwrap();

    // Unknown model: rejected at the front door.
    let resp = raw
        .request(&Request::post("/Evaluate", &eval_body("nope",
                                                        &[vec![1.0]])))
        .unwrap();
    assert_eq!(resp.status, 500);
    assert!(resp.body_str().unwrap().contains("unknown model"));

    // Metadata before any registration: retryable 503 (the contract is
    // learned, not hardcoded — the balancer genuinely does not know).
    let resp = raw
        .request(&Request::post("/InputSizes",
                                &json::write(&Value::obj(vec![(
                                    "name", Value::str("alpha"))]))))
        .unwrap();
    assert_eq!(resp.status, 503);
    assert!(resp.headers.contains_key("retry-after"));

    // /Info still lists the configured model.
    let resp = raw.request(&Request::get("/Info")).unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.body_str().unwrap().contains("alpha"));
    lb.shutdown();
}

#[test]
fn missing_name_defaults_on_single_model_front() {
    let mut lb = start(BalancerConfig {
        models: vec!["alpha".into()],
        ..Default::default()
    });
    let url = lb.url();
    wait_servers(&lb, 1);
    let body = json::write(&Value::obj(vec![
        ("input", Value::from_f64s2(&[vec![1.0, 2.0]])),
        ("config", Value::Obj(Default::default())),
    ]));
    let mut raw = HttpClient::connect(&url).unwrap();
    let resp = raw.request(&Request::post("/Evaluate", &body)).unwrap();
    // The single-model front door routes name-less requests rather
    // than rejecting them, so the request must have been *dispatched*
    // (the model server's own protocol validation then answers it —
    // the front injects nothing into the forwarded body).
    assert_eq!(resp.status, 500);
    let st = lb.stats().model("alpha").unwrap();
    assert_eq!(st.errors.load(Ordering::Relaxed), 1,
               "name-less request must be forwarded, not front-rejected");
    assert_eq!(st.served.load(Ordering::Relaxed), 0);
    lb.shutdown();
}
