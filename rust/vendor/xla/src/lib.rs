//! Offline stub of the `xla` PJRT bindings used by `uqsched::runtime`.
//!
//! The build container has no XLA/PJRT shared library, so this crate
//! provides the exact type/method surface the runtime layer compiles
//! against while reporting "PJRT unavailable" at runtime.  Everything
//! that needs real compute (the live serving plane, `runtime_*`
//! integration tests, figure benches) already self-skips when the
//! artifact directory is missing, so the scheduler simulation plane —
//! the part of the reproduction under active development — builds and
//! tests fully offline.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the real crate);
//! no call-site changes are required.

/// Error type mirroring the binding crate's debug-printable errors.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "PJRT runtime unavailable: uqsched was built against the offline \
         xla stub (vendor/xla); install the real bindings to enable the \
         compute plane"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}
