//! Offline shim of the `anyhow` crate: the subset of its API this
//! workspace uses, with the same semantics.
//!
//! The build environment has no crates.io access, so the real `anyhow`
//! cannot be fetched.  This shim provides:
//!
//!   * [`Error`] — a context chain over an optional source error, with
//!     [`Error::downcast_ref`] reaching the original typed source.
//!   * [`Result`] — `Result<T, Error>` with a defaulted error type.
//!   * [`anyhow!`] / [`bail!`] — format-style error construction.
//!   * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!     (both std-error and `anyhow::Error` variants) and `Option`.
//!
//! As in real anyhow, `Error` deliberately does NOT implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` conversion (the `?` operator) coherent.

use std::fmt::{self, Debug, Display};

/// Dynamic error: a stack of context messages over an optional source.
pub struct Error {
    /// Context messages, outermost (most recently attached) first.
    msgs: Vec<String>,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (the `anyhow!` entry point).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()], source: None }
    }

    /// Attach an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// Borrow the typed source error, if the chain bottoms out in one.
    pub fn downcast_ref<E>(&self) -> Option<&E>
    where
        E: std::error::Error + 'static,
    {
        self.source.as_ref().and_then(|s| s.downcast_ref::<E>())
    }

    /// The outermost message (or the source's rendering).
    fn outermost(&self) -> String {
        match self.msgs.first() {
            Some(m) => m.clone(),
            None => match &self.source {
                Some(s) => s.to_string(),
                None => "unknown error".to_string(),
            },
        }
    }

    /// Full chain, outermost first, `": "`-joined (the `{:#}` rendering).
    fn chain_string(&self) -> String {
        let mut parts: Vec<String> = self.msgs.clone();
        if let Some(s) = &self.source {
            parts.push(s.to_string());
        }
        if parts.is_empty() {
            parts.push("unknown error".to_string());
        }
        parts.join(": ")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain_string())
        } else {
            write!(f, "{}", self.outermost())
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain_string())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msgs: Vec::new(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: `Result` with the error defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context attachment for fallible values.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_chains_and_downcasts() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert!(format!("{e:#}").contains("eof"));
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
        fn f() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
